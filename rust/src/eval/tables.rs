//! Table printers regenerating the layout of the paper's Tables 2–7:
//! MAP-rate tables and training/testing speedup tables, one row per
//! dataset, one column per method, KDA as the speedup reference.

use std::fmt::Write as _;

use super::MethodResult;

/// Results for one dataset row: method name → result.
#[derive(Debug, Clone)]
pub struct DatasetRow {
    pub dataset: String,
    pub results: Vec<MethodResult>,
}

impl DatasetRow {
    pub fn get(&self, method: &str) -> Option<&MethodResult> {
        self.results.iter().find(|r| r.method == method)
    }
}

/// Paper column order (Tables 2–7), extended with the approximate-AKDA
/// columns from the `approx` subsystem.
pub const METHOD_COLUMNS: &[&str] = &[
    "pca", "lda", "lsvm", "kda", "gda", "srkda", "akda", "akda-nystrom",
    "akda-rff", "ksvm", "ksda", "gsda", "aksda",
];

/// Render a MAP table (Tables 2–4 layout) with a trailing Average row.
pub fn map_table(title: &str, rows: &[DatasetRow]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{title}");
    let _ = write!(out, "{:<12}", "dataset");
    for m in METHOD_COLUMNS {
        let _ = write!(out, "{:>14}", m);
    }
    let _ = writeln!(out);
    let mut sums = vec![0.0; METHOD_COLUMNS.len()];
    let mut counts = vec![0usize; METHOD_COLUMNS.len()];
    for row in rows {
        let _ = write!(out, "{:<12}", row.dataset);
        for (ci, m) in METHOD_COLUMNS.iter().enumerate() {
            match row.get(m) {
                Some(r) => {
                    let _ = write!(out, "{:>13.2}%", 100.0 * r.map);
                    sums[ci] += r.map;
                    counts[ci] += 1;
                }
                None => {
                    let _ = write!(out, "{:>14}", "-");
                }
            }
        }
        let _ = writeln!(out);
    }
    if rows.len() > 1 {
        let _ = write!(out, "{:<12}", "Average");
        for ci in 0..METHOD_COLUMNS.len() {
            if counts[ci] > 0 {
                let _ = write!(out, "{:>13.2}%", 100.0 * sums[ci] / counts[ci] as f64);
            } else {
                let _ = write!(out, "{:>14}", "-");
            }
        }
        let _ = writeln!(out);
    }
    out
}

/// Render a train/test speedup table (Tables 5–7 layout): entries are
/// `train_speedup/test_speedup` relative to the KDA column.
pub fn speedup_table(title: &str, rows: &[DatasetRow]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{title}");
    let _ = write!(out, "{:<12}", "dataset");
    for m in METHOD_COLUMNS {
        let _ = write!(out, "{:>14}", m);
    }
    let _ = writeln!(out);
    for row in rows {
        let Some(kda) = row.get("kda") else { continue };
        let kda = kda.clone();
        let _ = write!(out, "{:<12}", row.dataset);
        for m in METHOD_COLUMNS {
            match row.get(m) {
                Some(r) => {
                    let (t, p) = r.speedup_over(&kda);
                    let _ = write!(out, "{:>14}", format!("{}/{}", fmt_ratio(t), fmt_ratio(p)));
                }
                None => {
                    let _ = write!(out, "{:>14}", "-");
                }
            }
        }
        let _ = writeln!(out);
    }
    out
}

fn fmt_ratio(r: f64) -> String {
    if r >= 100.0 {
        format!("{r:.0}")
    } else if r >= 10.0 {
        format!("{r:.1}")
    } else {
        format!("{r:.2}")
    }
}

/// Render the peak-resident-tile table for streaming runs: entries are the
/// peak resident f64 count of each method's training accumulator (the
/// `da::akda_stream` B·m + m² + m·C tiles), "-" for methods that ran
/// fully in memory.
pub fn memory_table(title: &str, rows: &[DatasetRow]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{title}");
    let _ = write!(out, "{:<12}", "dataset");
    for m in METHOD_COLUMNS {
        let _ = write!(out, "{:>14}", m);
    }
    let _ = writeln!(out);
    for row in rows {
        let _ = write!(out, "{:<12}", row.dataset);
        for m in METHOD_COLUMNS {
            match row.get(m).and_then(|r| r.peak_f64) {
                Some(peak) => {
                    let _ = write!(out, "{:>14}", fmt_f64_count(peak));
                }
                None => {
                    let _ = write!(out, "{:>14}", "-");
                }
            }
        }
        let _ = writeln!(out);
    }
    out
}

/// Human-readable size of an f64 count (8 bytes each).
fn fmt_f64_count(n: usize) -> String {
    let bytes = (n as f64) * 8.0;
    if bytes >= 1e9 {
        format!("{:.2}GB", bytes / 1e9)
    } else if bytes >= 1e6 {
        format!("{:.1}MB", bytes / 1e6)
    } else {
        format!("{:.1}KB", bytes / 1e3)
    }
}

/// Machine-readable CSV dump next to the pretty table (for EXPERIMENTS.md
/// and plotting). `peak_f64` is empty for in-memory runs; `m` is the
/// landmark/random-feature budget (CV-selected when `--cv` searched
/// `m_grid`), empty for exact methods.
pub fn results_csv(rows: &[DatasetRow]) -> String {
    let mut out = String::from("dataset,method,map,train_s,test_s,peak_f64,m\n");
    for row in rows {
        for r in &row.results {
            let peak = r.peak_f64.map(|p| p.to_string()).unwrap_or_default();
            let m = r.budget.map(|m| m.to_string()).unwrap_or_default();
            let _ = writeln!(
                out,
                "{},{},{:.6},{:.6},{:.6},{},{}",
                row.dataset, r.method, r.map, r.train_s, r.test_s, peak, m
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> DatasetRow {
        DatasetRow {
            dataset: "toy".into(),
            results: vec![
                MethodResult {
                    method: "kda".into(),
                    map: 0.5,
                    train_s: 10.0,
                    test_s: 1.0,
                    peak_f64: None,
                    budget: None,
                },
                MethodResult {
                    method: "akda".into(),
                    map: 0.6,
                    train_s: 0.5,
                    test_s: 1.0,
                    peak_f64: None,
                    budget: None,
                },
                MethodResult {
                    method: "akda-nystrom".into(),
                    map: 0.6,
                    train_s: 0.4,
                    test_s: 1.0,
                    peak_f64: Some(200_000),
                    budget: Some(64),
                },
            ],
        }
    }

    #[test]
    fn map_table_contains_values_and_average() {
        let t = map_table("Table X", &[row(), row()]);
        assert!(t.contains("50.00%"));
        assert!(t.contains("60.00%"));
        assert!(t.contains("Average"));
        assert!(t.contains("akda"));
        // the approx subsystem's columns are part of the layout
        assert!(t.contains("akda-nystrom"));
        assert!(t.contains("akda-rff"));
    }

    #[test]
    fn speedup_table_reports_ratio() {
        let t = speedup_table("Table Y", &[row()]);
        assert!(t.contains("20.0/1.00"), "table:\n{t}");
        assert!(t.contains("1.00/1.00"));
    }

    #[test]
    fn csv_roundtrip_fields() {
        let c = results_csv(&[row()]);
        assert!(c.lines().count() == 4);
        assert!(c.starts_with("dataset,method,map,train_s,test_s,peak_f64,m\n"));
        assert!(c.contains("toy,akda,0.600000"));
        // streaming runs carry their residency + budget, exact rows leave
        // both trailing fields empty
        assert!(c.contains("toy,akda-nystrom,0.600000,0.400000,1.000000,200000,64"));
        assert!(c.contains("toy,kda,0.500000,10.000000,1.000000,,\n"));
    }

    #[test]
    fn memory_table_shows_streaming_residency_only() {
        let t = memory_table("Table Z", &[row()]);
        // 200_000 f64 = 1.6 MB
        assert!(t.contains("1.6MB"), "table:\n{t}");
        // in-memory methods show a dash
        let kda_col = t.lines().nth(1).unwrap();
        assert!(kda_col.contains("kda"));
        assert!(t.lines().nth(2).unwrap().contains('-'));
    }
}
