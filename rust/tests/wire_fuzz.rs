//! Deterministic fuzz-style torture of the `akda-wire/1` codec.
//!
//! A seeded PRNG (`akda::util::rng::Rng` — the crate's reproducibility
//! spine) generates hundreds of random frames of every type. The codec
//! must satisfy, bit for bit and on every run:
//!
//! * **Round trip** — `decode(encode(f)) == (f, encode(f).len())`.
//! * **Tamper rejection** — XOR-ing any single byte of a valid frame's
//!   bytes always makes `decode` return an error (the frame checksum
//!   covers the entire frame except itself; length mutations fall out
//!   as `Incomplete` or a checksum mismatch).
//! * **Truncation** — every strict prefix of a valid frame decodes to
//!   `Incomplete`, never `Ok` and never a panic.
//! * **Garbage** — random byte blobs never decode and never panic.
//!
//! Everything is seeded, so a pass here is a pass forever — this is a
//! regression net, not a flaky fuzzer.

use akda::coordinator::wire::{decode, encode, DecodeError, ErrorCode, Frame, WireModel};
use akda::util::rng::Rng;

/// Random wire-safe string (model ids, error messages).
fn rand_str(rng: &mut Rng, max_len: usize) -> String {
    const CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789-_/. ";
    let len = rng.below(max_len + 1);
    (0..len).map(|_| CHARS[rng.below(CHARS.len())] as char).collect()
}

/// Random finite-or-infinite f64s (NaN is excluded here because `Frame`
/// equality is `PartialEq` over f64 — NaN round-tripping is pinned
/// separately, byte-for-byte, in `nan_features_round_trip_bitforbit`).
fn rand_f64s(rng: &mut Rng, max_len: usize) -> Vec<f64> {
    let len = rng.below(max_len + 1);
    (0..len)
        .map(|_| match rng.below(16) {
            0 => f64::INFINITY,
            1 => f64::NEG_INFINITY,
            2 => 0.0,
            3 => -0.0,
            4 => f64::MIN_POSITIVE,
            _ => rng.range(-1e6, 1e6),
        })
        .collect()
}

fn rand_code(rng: &mut Rng) -> ErrorCode {
    ErrorCode::from_u8(1 + rng.below(5) as u8).expect("codes 1..=5 are all valid")
}

/// Random server-timing echo: `(stage id, nanos)` pairs, frequently
/// empty — the canonical encoding elides an empty echo entirely, so the
/// elided form must keep round-tripping too.
fn rand_timings(rng: &mut Rng) -> Vec<(u8, u64)> {
    let len = rng.below(6);
    (0..len).map(|_| (1 + rng.below(5) as u8, rng.next_u64())).collect()
}

/// One random frame of a random type.
fn rand_frame(rng: &mut Rng) -> Frame {
    let req_id = rng.next_u64();
    match rng.below(7) {
        0 => Frame::ScoreRequest {
            req_id,
            model: rand_str(rng, 24),
            features: rand_f64s(rng, 48),
            // 0 half the time: the untraced (trace-elided) form must
            // keep round-tripping alongside the traced extension
            trace: if rng.below(2) == 0 { 0 } else { rng.next_u64() | 1 },
        },
        1 => Frame::ScoreResponse {
            req_id,
            scores: rand_f64s(rng, 16),
            timings: rand_timings(rng),
        },
        2 => Frame::Error {
            req_id,
            code: rand_code(rng),
            retry_after_ms: rng.next_u64() as u32,
            message: rand_str(rng, 120),
        },
        3 => Frame::ModelsRequest { req_id },
        4 => Frame::ModelsResponse {
            req_id,
            models: (0..rng.below(6))
                .map(|_| WireModel {
                    name: rand_str(rng, 24),
                    input_dim: rng.next_u64() as u32,
                    version: rng.next_u64() as u32,
                })
                .collect(),
        },
        5 => Frame::MetricsRequest { req_id },
        _ => Frame::MetricsResponse {
            req_id,
            payload: (0..rng.below(65)).map(|_| rng.next_u64() as u8).collect(),
        },
    }
}

/// Acceptance: every random frame of every type survives
/// encode → decode bit-for-bit, consuming exactly its own bytes.
#[test]
fn random_frames_round_trip_bitforbit() {
    let mut rng = Rng::new(0x57_69_72_65_66_75_7a_7a); // "wirefuzz"
    let mut seen_types = [false; 7];
    for _ in 0..400 {
        let frame = rand_frame(&mut rng);
        seen_types[match &frame {
            Frame::ScoreRequest { .. } => 0,
            Frame::ScoreResponse { .. } => 1,
            Frame::Error { .. } => 2,
            Frame::ModelsRequest { .. } => 3,
            Frame::ModelsResponse { .. } => 4,
            Frame::MetricsRequest { .. } => 5,
            Frame::MetricsResponse { .. } => 6,
        }] = true;
        let bytes = encode(&frame);
        let (back, consumed) = decode(&bytes).expect("a frame we encoded must decode");
        assert_eq!(consumed, bytes.len(), "decode must consume exactly one frame");
        assert_eq!(back, frame, "round trip must be bit-for-bit");
        // and re-encoding the decoded frame reproduces the exact bytes
        assert_eq!(encode(&back), bytes, "re-encode must be byte-identical");
    }
    assert!(seen_types.iter().all(|&t| t), "400 draws must cover all 7 frame types");
}

/// Acceptance: NaN payloads cross the wire byte-for-byte (scores can
/// legitimately be NaN; the codec must not normalize the bit pattern).
#[test]
fn nan_features_round_trip_bitforbit() {
    let frame = Frame::ScoreResponse {
        req_id: 7,
        scores: vec![f64::NAN, 1.0, f64::from_bits(0x7ff8_dead_beef_0001)],
        timings: Vec::new(),
    };
    let bytes = encode(&frame);
    let (back, consumed) = decode(&bytes).expect("NaN frames must decode");
    assert_eq!(consumed, bytes.len());
    // Frame is PartialEq over f64, so compare through the bit patterns
    match &back {
        Frame::ScoreResponse { req_id, scores, .. } => {
            assert_eq!(*req_id, 7);
            let got: Vec<u64> = scores.iter().map(|v| v.to_bits()).collect();
            let want: Vec<u64> = match &frame {
                Frame::ScoreResponse { scores, .. } => {
                    scores.iter().map(|v| v.to_bits()).collect()
                }
                _ => unreachable!(),
            };
            assert_eq!(got, want, "NaN bit patterns must survive the wire");
        }
        other => panic!("expected a ScoreResponse back, got {other:?}"),
    }
    assert_eq!(encode(&back), bytes, "re-encode must be byte-identical");
}

/// Acceptance: the metrics-scrape frames (`akda client --metrics`)
/// round-trip — the request is header-only plus its id, the response
/// carries the opaque `akda-metrics/1` snapshot payload verbatim.
#[test]
fn metrics_frames_round_trip() {
    let req = Frame::MetricsRequest { req_id: 41 };
    let bytes = encode(&req);
    let (back, n) = decode(&bytes).expect("MetricsRequest must decode");
    assert_eq!(n, bytes.len());
    assert_eq!(back, req);

    let payload = br#"{"schema":"akda-metrics/1","counters":{}}"#.to_vec();
    let resp = Frame::MetricsResponse { req_id: 42, payload: payload.clone() };
    let bytes = encode(&resp);
    let (back, n) = decode(&bytes).expect("MetricsResponse must decode");
    assert_eq!(n, bytes.len());
    match &back {
        Frame::MetricsResponse { req_id, payload: got } => {
            assert_eq!(*req_id, 42);
            assert_eq!(*got, payload, "the snapshot payload must cross the wire verbatim");
        }
        other => panic!("expected a MetricsResponse back, got {other:?}"),
    }
    assert_eq!(encode(&back), bytes, "re-encode must be byte-identical");
}

/// Acceptance: XOR-ing any random byte of a valid frame always makes
/// `decode` fail — the checksum (or a structural check) catches every
/// single-byte corruption, at every offset class (magic, version, type,
/// length, checksum, body).
#[test]
fn any_single_byte_mutation_is_rejected() {
    let mut rng = Rng::new(0x6d_75_74_61_74_65_5f_31); // "mutate_1"
    for _ in 0..150 {
        let frame = rand_frame(&mut rng);
        let bytes = encode(&frame);
        // 8 random single-byte corruptions per frame, plus the first and
        // last byte explicitly (magic and body/checksum tail)
        let mut offsets: Vec<usize> = (0..8).map(|_| rng.below(bytes.len())).collect();
        offsets.push(0);
        offsets.push(bytes.len() - 1);
        for off in offsets {
            let mask = 1u8 << rng.below(8);
            let mut evil = bytes.clone();
            evil[off] ^= mask;
            match decode(&evil) {
                Ok((got, _)) => panic!(
                    "flipping bit {mask:#04x} at byte {off}/{} went undetected: {got:?}",
                    bytes.len()
                ),
                Err(DecodeError::Incomplete { need }) => {
                    // only a length-field mutation can look incomplete —
                    // and then the claimed total must exceed what we hold
                    assert!((6..10).contains(&off), "Incomplete from byte {off}?");
                    assert!(need > evil.len());
                }
                Err(DecodeError::Malformed(_)) => {}
            }
        }
    }
}

/// Acceptance: every strict prefix of a valid frame is `Incomplete` —
/// a streaming reader can never mis-parse a half-received frame.
#[test]
fn every_strict_prefix_is_incomplete() {
    let mut rng = Rng::new(0x70_72_65_66_69_78_5f_31); // "prefix_1"
    for _ in 0..24 {
        let frame = rand_frame(&mut rng);
        let bytes = encode(&frame);
        for cut in 0..bytes.len() {
            match decode(&bytes[..cut]) {
                Err(DecodeError::Incomplete { need }) => {
                    assert!(need > cut, "need ({need}) must exceed the prefix ({cut})");
                    // once the header is visible, `need` is exact
                    if cut >= 18 {
                        assert_eq!(need, bytes.len());
                    }
                }
                other => panic!(
                    "prefix of {cut}/{} bytes must be Incomplete, got {other:?}",
                    bytes.len()
                ),
            }
        }
    }
}

/// Acceptance: random garbage never decodes and never panics. (Blobs
/// that happen to be shorter than a header legitimately report
/// `Incomplete`; nothing random ever reports `Ok`.)
#[test]
fn random_garbage_never_decodes() {
    let mut rng = Rng::new(0x67_61_72_62_61_67_65_31); // "garbage1"
    for _ in 0..300 {
        let len = rng.below(257);
        let blob: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        if let Ok((frame, _)) = decode(&blob) {
            panic!("random garbage decoded to {frame:?}");
        }
    }
}
