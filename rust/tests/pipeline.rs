//! Integration: coordinator pipeline over the dataset registry — the
//! protocol, CV, work pool, scoring service, and the method ordering the
//! paper's tables claim (kernel > linear on nonlinear data; subclass ≥
//! class on multimodal data; AKDA ≫ KDA in training time).

use std::sync::Arc;
use std::time::Duration;

use akda::coordinator::{
    evaluate_ovr, select_hyper, DetectorBank, EvalConfig, Hyper, MethodId, ScoringService,
    WorkPool,
};
use akda::da::DrMethod;
use akda::data::{by_name, synthetic, Condition, Split};
use akda::kernels::Kernel;
use akda::svm::{LinearSvm, LinearSvmConfig};

fn tiny_split() -> Split {
    let mut d = by_name("mscorid").unwrap();
    d.n_classes = 5;
    d.test_per_class = 25;
    d.split(Condition::Ex10)
}

#[test]
fn full_eval_row_all_methods() {
    // one full table row: every method column on one dataset
    let split = tiny_split();
    let pool = WorkPool::new(4);
    let hp = Hyper { rho: 0.05, c: 1.0, h: 2, m: 24, ..Default::default() };
    let mut maps = std::collections::BTreeMap::new();
    for id in MethodId::table_columns() {
        let res = evaluate_ovr(&split, id, hp, 1e-3, None, Some(&pool)).unwrap();
        assert!(res.map.is_finite() && res.map >= 0.0 && res.map <= 1.0);
        assert!(res.train_s > 0.0);
        maps.insert(id.name(), res);
    }
    // the paper's training-time ordering: AKDA must beat KDA clearly
    let kda = &maps["kda"];
    let akda = &maps["akda"];
    assert!(
        kda.train_s / akda.train_s > 1.5,
        "AKDA {:.3}s should be well under KDA {:.3}s",
        akda.train_s,
        kda.train_s
    );
    // AKDA accuracy competitive with KDA (within 5 MAP points on this toy)
    assert!(akda.map > kda.map - 0.05, "akda {} vs kda {}", akda.map, kda.map);
}

#[test]
fn kernel_methods_beat_linear_on_shells() {
    // concentric shells: linearly inseparable — the regime motivating
    // kernel DA (Sec. 1). AKDA must dominate LDA/LSVM.
    let (x, y) = synthetic::concentric_shells(60, 6, 3);
    let (xt, yt) = synthetic::concentric_shells(80, 6, 4);
    let split = Split { x_train: x, y_train: y, x_test: xt, y_test: yt, n_classes: 2 };
    let hp = Hyper { rho: 0.5, c: 1.0, h: 2, ..Default::default() };
    let akda = evaluate_ovr(&split, MethodId::Akda, hp, 1e-3, None, None).unwrap();
    let lda = evaluate_ovr(&split, MethodId::Lda, hp, 1e-3, None, None).unwrap();
    let lsvm = evaluate_ovr(&split, MethodId::Lsvm, hp, 1e-3, None, None).unwrap();
    assert!(akda.map > 0.9, "akda MAP {}", akda.map);
    assert!(akda.map > lda.map + 0.15, "akda {} vs lda {}", akda.map, lda.map);
    assert!(akda.map > lsvm.map + 0.15, "akda {} vs lsvm {}", akda.map, lsvm.map);
}

#[test]
fn subclass_methods_beat_class_methods_on_xor() {
    // multimodal XOR blobs: subclass criterion wins (Sec. 5 motivation)
    let (x, y) = synthetic::xor_blobs(30, 4, 3.0, 0.4, 5);
    let (xt, yt) = synthetic::xor_blobs(40, 4, 3.0, 0.4, 6);
    let split = Split { x_train: x, y_train: y, x_test: xt, y_test: yt, n_classes: 2 };
    let run = |dr: &dyn DrMethod| {
        let proj = dr.fit(&split.x_train, &split.y_train, 2).unwrap();
        let z_tr = proj.project(&split.x_train);
        let z_te = proj.project(&split.x_test);
        let ypm: Vec<f64> = split.y_train.iter()
            .map(|&l| if l == 0 { 1.0 } else { -1.0 }).collect();
        let svm = LinearSvm::train(&z_tr, &ypm, LinearSvmConfig::default());
        let scores = svm.decision_batch(&z_te);
        let pos: Vec<bool> = split.y_test.iter().map(|&l| l == 0).collect();
        akda::eval::average_precision(&scores, &pos)
    };
    // unimodal DA with a linear kernel is a linear map of x — provably
    // blind to XOR (class means coincide)
    let akda_lin = run(&akda::da::akda::Akda {
        kernel: Kernel::Linear, eps: 1e-2, block: 32 });
    // the subclass criterion + RBF kernel resolves the blob structure
    let aksda_rbf = run(&akda::da::aksda::Aksda {
        kernel: Kernel::Rbf { rho: 0.3 }, eps: 1e-3, h_per_class: 2, seed: 3, block: 32 });
    assert!(akda_lin < 0.75, "linear unimodal DA should fail on XOR: {akda_lin}");
    assert!(aksda_rbf > 0.9, "aksda-rbf on xor: {aksda_rbf}");
    assert!(aksda_rbf > akda_lin + 0.2);
}

#[test]
fn cv_improves_or_matches_fixed_hyper() {
    let split = tiny_split();
    let cfg = EvalConfig {
        rho_grid: vec![0.005, 0.05, 0.5],
        c_grid: vec![1.0],
        h_grid: vec![2],
        cv_folds: 2,
        ..Default::default()
    };
    let hp_cv = select_hyper(&split, MethodId::Akda, &cfg, None).unwrap();
    let res_cv =
        evaluate_ovr(&split, MethodId::Akda, hp_cv, 1e-3, None, None).unwrap();
    // the worst grid point as the comparison baseline
    let mut worst = f64::INFINITY;
    for &rho in &cfg.rho_grid {
        let r = evaluate_ovr(
            &split,
            MethodId::Akda,
            Hyper { rho, c: 1.0, h: 2, ..Default::default() },
            1e-3,
            None,
            None,
        )
        .unwrap();
        worst = worst.min(r.map);
    }
    assert!(res_cv.map >= worst - 1e-9, "CV pick {} vs worst {}", res_cv.map, worst);
}

#[test]
fn detector_bank_service_end_to_end() {
    let split = tiny_split();
    let projection = akda::da::akda::Akda::new(Kernel::Rbf { rho: 0.05 })
        .fit(&split.x_train, &split.y_train, split.n_classes)
        .unwrap();
    let z = projection.project(&split.x_train);
    let svms = (0..split.n_classes)
        .map(|cls| {
            let y: Vec<f64> = split
                .y_train
                .iter()
                .map(|&l| if l == cls { 1.0 } else { -1.0 })
                .collect();
            (format!("c{cls}"), LinearSvm::train(&z, &y, LinearSvmConfig::default()))
        })
        .collect();
    let bank = Arc::new(DetectorBank { projection, svms });
    assert_eq!(bank.class_names().len(), split.n_classes);
    let svc = ScoringService::start(
        bank,
        split.x_train.cols(),
        16,
        Duration::from_millis(3),
    );
    let client = svc.client();
    // concurrent scoring of 40 test rows
    let mut correct = 0;
    std::thread::scope(|s| {
        let mut hs = Vec::new();
        for i in 0..40 {
            let client = client.clone();
            let row = split.x_test.row(i).to_vec();
            hs.push(s.spawn(move || client.score(row).unwrap()));
        }
        for (i, h) in hs.into_iter().enumerate() {
            let scores = h.join().unwrap();
            let pred = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == split.y_test[i] {
                correct += 1;
            }
        }
    });
    // mscorid-like data is easy; the service must classify most test rows
    assert!(correct >= 25, "correct={correct}/40");
}

#[test]
fn registry_shapes_feed_protocol() {
    // every registry dataset yields a consistent split that the protocol
    // can evaluate (smoke over the full Table-1 inventory, 10Ex, one
    // cheap method)
    for spec in akda::data::cross_dataset_collection() {
        let split = spec.split(Condition::Ex10);
        assert_eq!(split.y_train.len(), spec.n_classes * 10);
        let res = evaluate_ovr(
            &split,
            MethodId::Pca,
            Hyper::default(),
            1e-3,
            None,
            None,
        )
        .unwrap();
        assert!(res.map > 0.0, "{}", spec.name);
    }
}
