//! Integration: the TCP network edge (L8) — `NetServer` in front of a
//! `FleetService`, speaking akda-wire/1.
//!
//! Pins the PR's acceptance guarantees:
//!
//! 1. **Protocol torture** — truncated frames, oversized length
//!    prefixes, wrong magic, mid-frame disconnects, garbage bytes, and
//!    interleaved pipelined requests are answered with typed error
//!    frames or a clean close, never a panic, and never disturb other
//!    connections or tenants.
//! 2. **Bit-for-bit transport** — scores over TCP equal the in-process
//!    `FleetClient` scores exactly (f64s cross the wire as LE bytes).
//! 3. **Live fleet underneath** — a republished tenant hot-swaps
//!    visibly over TCP while the other tenant's open connections keep
//!    answering, and a NEW model name published to the registry becomes
//!    scorable over the already-open listener without restart.
//! 4. **Backpressure** — a tiny ingress queue sheds the oldest requests
//!    with typed `OverCapacity` frames (never hangs), counts them in
//!    `akda_net_sheds_total`, and the queue-depth gauge recovers to 0.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use akda::coordinator::net::{NetClient, NetOptions, NetReply, NetServer};
use akda::coordinator::wire::{encode, ErrorCode, Frame, MAGIC, MAX_BODY_LEN, VERSION};
use akda::coordinator::{DetectorBank, FleetOptions, FleetService};
use akda::da::akda::Akda;
use akda::data::synthetic::{gaussian_classes, GaussianSpec};
use akda::kernels::Kernel;
use akda::linalg::Mat;
use akda::model::codec::{encode_resume, ExactResume};
use akda::model::update::train_svm_bank;
use akda::model::{encode_bank, ModelArtifact, ModelManifest, ModelRegistry, ResumeState};

const RECV_TIMEOUT: Duration = Duration::from_secs(30);

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("akda_net_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Train one publishable exact-AKDA tenant (rows returned for requests) —
/// the same shape `akda train --method akda` publishes.
fn trained_artifact(dim: usize, n_classes: usize, seed: u64) -> (Mat, ModelArtifact) {
    let (x, labels) = gaussian_classes(&GaussianSpec {
        n_classes,
        n_per_class: vec![12; n_classes],
        dim,
        class_sep: 2.5,
        noise: 0.6,
        modes_per_class: 1,
        seed,
    });
    let akda_cfg = Akda::new(Kernel::Rbf { rho: 0.4 });
    let (proj, chol_l) = akda_cfg.fit_with_factor(&x, &labels, n_classes).unwrap();
    let z = proj.project(&x);
    let svms = train_svm_bank(&z, &labels, n_classes);
    let bank = DetectorBank { projection: Box::new(proj), svms };
    let mut art = encode_bank(&bank, "akda").unwrap();
    encode_resume(
        &mut art,
        &ResumeState::Exact(ExactResume {
            chol_l,
            labels: labels.clone(),
            eps: akda_cfg.eps,
            n_classes,
        }),
    )
    .unwrap();
    (x, art)
}

fn manifest(dim: usize, n_classes: usize) -> ModelManifest {
    ModelManifest {
        method: "akda".into(),
        n_classes,
        input_dim: dim,
        ..Default::default()
    }
}

/// Registry with tenants `aa` (6 features / 3 classes) and `bb`
/// (5 features / 2 classes), plus their request rows.
fn two_tenant_registry(tag: &str, seed: u64) -> (PathBuf, ModelRegistry, Mat, Mat) {
    let root = tmpdir(tag);
    let registry = ModelRegistry::open(&root);
    let (xa, art_a) = trained_artifact(6, 3, seed);
    let (xb, art_b) = trained_artifact(5, 2, seed + 1);
    registry.publish("aa", &art_a, &manifest(6, 3)).unwrap();
    registry.publish("bb", &art_b, &manifest(5, 2)).unwrap();
    (root, registry, xa, xb)
}

fn connect(server: &NetServer) -> NetClient {
    NetClient::connect(server.local_addr(), RECV_TIMEOUT).unwrap()
}

/// Acceptance: every malformed-input case is answered with a typed error
/// frame or a clean close — zero panics — while a healthy connection on
/// the same server keeps scoring undisturbed throughout.
#[test]
fn torture_malformed_input_never_panics_and_never_disturbs_others() {
    let (root, registry, xa, _xb) = two_tenant_registry("torture", 31);
    let svc = FleetService::start(&registry, FleetOptions::default()).unwrap();
    let server = NetServer::start("127.0.0.1:0", svc.client(), NetOptions::default()).unwrap();

    // the canary: a good connection opened BEFORE the torture, used
    // between every case — the abuse must never reach it
    let mut canary = connect(&server);
    let canary_scores = match canary.score("aa", xa.row(0)).unwrap() {
        NetReply::Scores(s) => s,
        other => panic!("canary must score, got {other:?}"),
    };
    assert_eq!(canary_scores.len(), 3);

    let assert_canary_alive = |canary: &mut NetClient| {
        match canary.score("aa", xa.row(0)).unwrap() {
            NetReply::Scores(s) => assert_eq!(s, canary_scores, "canary scores must not drift"),
            other => panic!("canary must keep scoring, got {other:?}"),
        }
    };

    // -- wrong magic: typed BadFrame answer, then the connection closes
    let mut c = connect(&server);
    c.send_raw(b"XXXXGARBAGE-NOT-A-FRAME-AT-ALL").unwrap();
    match c.recv().unwrap() {
        Frame::Error { code: ErrorCode::BadFrame, req_id: 0, .. } => {}
        other => panic!("wrong magic must get a typed BadFrame, got {other:?}"),
    }
    assert!(c.recv().is_err(), "the abused connection must be closed");
    assert_canary_alive(&mut canary);

    // -- oversized length prefix: rejected from the header alone (the
    // server must never try to buffer the claimed body)
    let mut c = connect(&server);
    let mut header = Vec::new();
    header.extend_from_slice(&MAGIC);
    header.push(VERSION);
    header.push(1); // ScoreRequest
    header.extend_from_slice(&(MAX_BODY_LEN + 1).to_le_bytes());
    header.extend_from_slice(&[0u8; 8]); // checksum junk — len is checked first
    c.send_raw(&header).unwrap();
    match c.recv().unwrap() {
        Frame::Error { code: ErrorCode::BadFrame, message, .. } => {
            assert!(message.contains("oversized"), "{message}");
        }
        other => panic!("oversized len must get a typed BadFrame, got {other:?}"),
    }
    assert!(c.recv().is_err());
    assert_canary_alive(&mut canary);

    // -- corrupted body: one flipped bit fails the frame checksum
    let mut c = connect(&server);
    let mut bytes = encode(&Frame::ScoreRequest {
        req_id: 9,
        model: "aa".into(),
        features: xa.row(0).to_vec(),
        trace: 0,
    });
    bytes[20] ^= 0x01;
    c.send_raw(&bytes).unwrap();
    match c.recv().unwrap() {
        Frame::Error { code: ErrorCode::BadFrame, message, .. } => {
            assert!(message.contains("checksum"), "{message}");
        }
        other => panic!("a flipped bit must get a typed BadFrame, got {other:?}"),
    }
    assert_canary_alive(&mut canary);

    // -- truncated frame + disconnect: the peer vanishes mid-frame; the
    // server must just drop the connection (nothing to answer)
    let mut c = connect(&server);
    let bytes = encode(&Frame::ScoreRequest {
        req_id: 10,
        model: "aa".into(),
        features: xa.row(1).to_vec(),
        trace: 0,
    });
    c.send_raw(&bytes[..10]).unwrap();
    drop(c);
    assert_canary_alive(&mut canary);

    // -- clean half-close at a frame boundary: no reply, no error
    let mut c = connect(&server);
    c.shutdown_write().unwrap();
    assert!(c.recv().is_err(), "server closes in response to EOF");
    assert_canary_alive(&mut canary);

    // -- a response-type frame sent TO the server: protocol violation
    let mut c = connect(&server);
    c.send_raw(&encode(&Frame::ScoreResponse {
        req_id: 4,
        scores: vec![1.0],
        timings: Vec::new(),
    }))
    .unwrap();
    match c.recv().unwrap() {
        Frame::Error { code: ErrorCode::BadFrame, req_id: 4, .. } => {}
        other => panic!("a response frame at the server must be rejected, got {other:?}"),
    }
    assert_canary_alive(&mut canary);

    // -- wire-level protocol errors are typed too: unknown model id and
    // wrong feature width come back as error frames on a live connection
    let mut c = connect(&server);
    match c.score("nope", &[0.0; 6]).unwrap() {
        NetReply::Rejected { code: ErrorCode::UnknownModel, message, .. } => {
            assert!(message.contains("nope"), "{message}");
        }
        other => panic!("unknown model must be typed, got {other:?}"),
    }
    match c.score("aa", &[0.0; 4]).unwrap() {
        NetReply::Rejected { code: ErrorCode::WrongDim, message, .. } => {
            assert!(message.contains("expects 6"), "{message}");
        }
        other => panic!("wrong dim must be typed, got {other:?}"),
    }
    // ...and the SAME connection still scores afterwards
    match c.score("aa", xa.row(2)).unwrap() {
        NetReply::Scores(s) => assert_eq!(s.len(), 3),
        other => panic!("connection must survive typed rejections, got {other:?}"),
    }
    assert_canary_alive(&mut canary);

    drop(canary);
    drop(server);
    drop(svc);
    let _ = std::fs::remove_dir_all(&root);
}

/// Acceptance: one connection pipelines interleaved requests for BOTH
/// tenants without waiting; every reply is routed back by `req_id` and
/// is bit-for-bit equal to the in-process `FleetClient` answer.
#[test]
fn interleaved_pipelined_requests_route_replies_by_req_id() {
    let (root, registry, xa, xb) = two_tenant_registry("pipeline", 41);
    let svc = FleetService::start(&registry, FleetOptions::default()).unwrap();
    let fleet = svc.client();
    let server = NetServer::start("127.0.0.1:0", svc.client(), NetOptions::default()).unwrap();

    let mut c = connect(&server);
    // expected answers from the in-process client, keyed by wire req_id
    let mut expected = std::collections::BTreeMap::new();
    for i in 0..6 {
        let (model, row) = if i % 2 == 0 {
            ("aa", xa.row(i))
        } else {
            ("bb", xb.row(i))
        };
        let id = c.send_score(model, row).unwrap();
        expected.insert(id, fleet.score(model, row.to_vec()).unwrap());
    }
    // replies may arrive out of order (per-tenant batching) — collect all
    for _ in 0..expected.len() {
        match c.recv().unwrap() {
            Frame::ScoreResponse { req_id, scores, .. } => {
                let want = expected.remove(&req_id).expect("unknown or duplicate req_id");
                assert_eq!(scores, want, "TCP scores must be bit-for-bit in-process scores");
            }
            other => panic!("expected a ScoreResponse, got {other:?}"),
        }
    }
    assert!(expected.is_empty(), "every pipelined request must be answered exactly once");

    drop(c);
    drop(server);
    drop(fleet);
    drop(svc);
    let _ = std::fs::remove_dir_all(&root);
}

/// Acceptance: end to end over a live fleet — two tenants scored by
/// concurrent NetClients bit-for-bit against in-process scores; a
/// republish hot-swaps one tenant visibly over TCP while the OTHER
/// tenant's already-open connection keeps answering, unchanged.
#[test]
fn e2e_bitforbit_scores_and_hot_swap_over_open_connections() {
    let (root, registry, xa, xb) = two_tenant_registry("e2e", 51);
    let svc = FleetService::start(
        &registry,
        FleetOptions { watch: Some(Duration::from_millis(10)), ..Default::default() },
    )
    .unwrap();
    let fleet = svc.client();
    let server = NetServer::start("127.0.0.1:0", svc.client(), NetOptions::default()).unwrap();

    // the roster reports both tenants with their dims and versions
    let mut c = connect(&server);
    let roster = c.models().unwrap();
    let summary: Vec<(String, u32, u32)> =
        roster.iter().map(|m| (m.name.clone(), m.input_dim, m.version)).collect();
    assert_eq!(summary, vec![("aa".into(), 6, 1), ("bb".into(), 5, 1)]);

    // concurrent NetClients on both tenants: bit-for-bit vs in-process
    std::thread::scope(|s| {
        let mut joins = Vec::new();
        for w in 0..4 {
            let (fleet, server, xa, xb) = (fleet.clone(), &server, &xa, &xb);
            joins.push(s.spawn(move || {
                let mut c = connect(server);
                for i in 0..6 {
                    let (model, x): (&str, &Mat) = if (w + i) % 2 == 0 {
                        ("aa", xa)
                    } else {
                        ("bb", xb)
                    };
                    let row = x.row(i % x.rows());
                    let want = fleet.score(model, row.to_vec()).unwrap();
                    match c.score(model, row).unwrap() {
                        NetReply::Scores(got) => assert_eq!(got, want),
                        other => panic!("score failed: {other:?}"),
                    }
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    });

    // long-lived bb connection opened BEFORE the swap
    let mut bb_conn = connect(&server);
    let bb_before = match bb_conn.score("bb", xb.row(0)).unwrap() {
        NetReply::Scores(s) => s,
        other => panic!("bb must score, got {other:?}"),
    };
    let aa_before = match c.score("aa", xa.row(0)).unwrap() {
        NetReply::Scores(s) => s,
        other => panic!("aa must score, got {other:?}"),
    };

    // republish tenant "aa" (fresh fit, same shape) — the fleet watcher
    // hot-swaps it; the swap must become visible over TCP
    let (_, art_a2) = trained_artifact(6, 3, 99);
    registry.publish("aa", &art_a2, &manifest(6, 3)).unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let roster = c.models().unwrap();
        let aa_v = roster.iter().find(|m| m.name == "aa").unwrap().version;
        if aa_v == 2 {
            break;
        }
        assert!(Instant::now() < deadline, "aa@2 never became visible over TCP");
        std::thread::sleep(Duration::from_millis(10));
    }

    // the swapped tenant answers differently; the other tenant's open
    // connection is untouched — same connection, same bits
    let aa_after = match c.score("aa", xa.row(0)).unwrap() {
        NetReply::Scores(s) => s,
        other => panic!("aa must still score, got {other:?}"),
    };
    assert_ne!(aa_before, aa_after, "the republished model must actually serve");
    let bb_after = match bb_conn.score("bb", xb.row(0)).unwrap() {
        NetReply::Scores(s) => s,
        other => panic!("bb's open connection must stay live, got {other:?}"),
    };
    assert_eq!(bb_before, bb_after, "the un-swapped tenant must be bit-for-bit stable");

    drop(c);
    drop(bb_conn);
    drop(server);
    drop(fleet);
    drop(svc);
    let _ = std::fs::remove_dir_all(&root);
}

/// Acceptance: a NEW model name published to the registry is onboarded
/// by the watcher and becomes scorable over the ALREADY-OPEN listener —
/// and the already-open connection — without any restart.
#[test]
fn new_model_name_onboards_over_the_open_listener() {
    let root = tmpdir("onboard");
    let registry = ModelRegistry::open(&root);
    let (xa, art_a) = trained_artifact(6, 3, 61);
    registry.publish("aa", &art_a, &manifest(6, 3)).unwrap();

    let svc = FleetService::start(
        &registry,
        FleetOptions { watch: Some(Duration::from_millis(10)), ..Default::default() },
    )
    .unwrap();
    let server = NetServer::start("127.0.0.1:0", svc.client(), NetOptions::default()).unwrap();

    let mut c = connect(&server);
    let names: Vec<String> = c.models().unwrap().into_iter().map(|m| m.name).collect();
    assert_eq!(names, vec!["aa".to_string()]);
    // an unknown name is (typed-)rejected before onboarding...
    let (xz, art_z) = trained_artifact(4, 2, 62);
    match c.score("zz", xz.row(0)).unwrap() {
        NetReply::Rejected { code: ErrorCode::UnknownModel, .. } => {}
        other => panic!("zz must be unknown before publish, got {other:?}"),
    }

    // ...then the NEW name appears in the registry and joins the fleet
    registry.publish("zz", &art_z, &manifest(4, 2)).unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let names: Vec<String> = c.models().unwrap().into_iter().map(|m| m.name).collect();
        if names == vec!["aa".to_string(), "zz".to_string()] {
            break;
        }
        assert!(Instant::now() < deadline, "zz was never onboarded over TCP");
        std::thread::sleep(Duration::from_millis(10));
    }
    // scorable over the same connection that predates the publish
    match c.score("zz", xz.row(0)).unwrap() {
        NetReply::Scores(s) => assert_eq!(s.len(), 2),
        other => panic!("onboarded tenant must score, got {other:?}"),
    }
    // the original tenant is undisturbed
    match c.score("aa", xa.row(0)).unwrap() {
        NetReply::Scores(s) => assert_eq!(s.len(), 3),
        other => panic!("aa must keep scoring, got {other:?}"),
    }

    drop(c);
    drop(server);
    drop(svc);
    let _ = std::fs::remove_dir_all(&root);
}

/// Acceptance: with a tiny ingress queue and paced submission, a burst
/// of pipelined requests gets every excess request answered with a typed
/// `OverCapacity` frame carrying the configured retry hint (no hangs),
/// `akda_net_sheds_total` counts the sheds, and the queue-depth gauge
/// recovers to 0 after the burst.
#[test]
fn backpressure_sheds_oldest_with_typed_retry_and_recovers() {
    let root = tmpdir("shed");
    let registry = ModelRegistry::open(&root);
    let (xa, art_a) = trained_artifact(6, 3, 71);
    registry.publish("aa", &art_a, &manifest(6, 3)).unwrap();

    let svc = FleetService::start(&registry, FleetOptions::default()).unwrap();
    // queue of 2, one request in the fleet at a time, 7ms retry hint:
    // the dispatcher's micro-batch window makes each submission take
    // milliseconds while pipelined frames arrive in microseconds, so a
    // 50-deep burst MUST overflow the queue deterministically
    let opts = NetOptions { queue_cap: 2, max_inflight: 1, retry_after_ms: 7, ..Default::default() };
    let server = NetServer::start("127.0.0.1:0", svc.client(), opts).unwrap();
    let listen = server.local_addr().to_string();

    let burst = 50;
    let mut c = connect(&server);
    for i in 0..burst {
        c.send_score("aa", xa.row(i % xa.rows())).unwrap();
    }
    // every request gets an answer: scores or a typed shed — never a hang
    // (the canary for "hang" is the client's read timeout)
    let (mut scored, mut shed) = (0usize, 0usize);
    for _ in 0..burst {
        match c.recv().unwrap() {
            Frame::ScoreResponse { scores, .. } => {
                assert_eq!(scores.len(), 3);
                scored += 1;
            }
            Frame::Error { code: ErrorCode::OverCapacity, retry_after_ms, message, .. } => {
                assert_eq!(retry_after_ms, 7, "the shed must carry the retry hint");
                assert!(message.contains("retry"), "{message}");
                shed += 1;
            }
            other => panic!("expected scores or OverCapacity, got {other:?}"),
        }
    }
    assert_eq!(scored + shed, burst);
    assert!(shed > 0, "a 50-deep burst against queue_cap=2 must shed");
    assert!(scored > 0, "the surviving requests must still be scored");

    // the sheds are counted, labeled by this listener
    let sheds_total = akda::obs::counter_with(
        "akda_net_sheds_total",
        &[("listen", &listen), ("reason", "queue_full")],
    )
    .get();
    assert_eq!(sheds_total as usize, shed, "every shed must be counted exactly once");

    // and the queue drains: depth gauge back to 0 after the burst
    let gauge = akda::obs::gauge_with("akda_net_queue_depth", &[("listen", &listen)]);
    let deadline = Instant::now() + Duration::from_secs(10);
    while (server.queue_depth() > 0 || gauge.get() != 0.0) && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(server.queue_depth(), 0, "the ingress queue must drain");
    assert_eq!(gauge.get(), 0.0, "the queue-depth gauge must recover to 0");

    drop(c);
    drop(server);
    drop(svc);
    let _ = std::fs::remove_dir_all(&root);
}
