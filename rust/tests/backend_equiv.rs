//! The backend seam's lockdown suite (L10): `Scalar`, `Blocked`, and
//! `Parallel` must be *observationally identical* — bit for bit — on
//! every routed dense operation, over a grid of shapes, tile heights,
//! kernels, and worker-pool sizes, including degenerate (1×1), non-
//! square, and numerically hostile inputs. The `auto` policy and the
//! `--backend` flag are pure performance choices only because this
//! suite holds; see `linalg::backend` for the determinism contract.
//!
//! CI runs this suite once per `AKDA_BACKEND` value (scalar / blocked /
//! parallel): the explicit-backend assertions are env-independent, and
//! the env override steers every *globally routed* entry point the
//! end-to-end test exercises, so all three lanes must stay green.

use std::sync::Arc;

use akda::coordinator::{build_dr, DetectorBank, Hyper, MethodId, WorkPool};
use akda::da::{DrMethod, Projection};
use akda::data::{by_name, Condition, Split};
use akda::kernels::{cross_gram_with, gram_with, Kernel};
use akda::linalg::backend::{self, Backend, BackendKind, Blocked, Parallel, Scalar};
use akda::linalg::chol::{cholesky_with, CholError};
use akda::linalg::mat::{accumulate_tn_with, matmul_into_with};
use akda::linalg::Mat;
use akda::model::{decode_bank, encode_bank, ModelArtifact};
use akda::svm::{LinearSvm, LinearSvmConfig};
use akda::util::rng::Rng;

fn randmat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.normal())
}

fn spd(n: usize, seed: u64) -> Mat {
    let a = randmat(n, n, seed);
    let mut m = a.matmul_nt(&a).scale(1.0 / n.max(1) as f64);
    m.add_ridge(1.0);
    m
}

/// Every backend variant under test for an `n`-row operation: the
/// scalar reference, cache tiles of height 1 / 7 / 64 / n (1 = maximal
/// tiling, n = single tile — the degenerate geometries most likely to
/// expose a schedule-dependent reduction), and the parallel backend on
/// both the shared global pool and a small pinned pool.
fn backends(n: usize, pinned: &Parallel) -> Vec<(String, Box<dyn Backend + '_>)> {
    let mut v: Vec<(String, Box<dyn Backend + '_>)> = vec![
        ("scalar".into(), Box::new(Scalar)),
        ("blocked-1".into(), Box::new(Blocked { tile: 1 })),
        ("blocked-7".into(), Box::new(Blocked { tile: 7 })),
        ("blocked-64".into(), Box::new(Blocked { tile: 64 })),
        (format!("blocked-{}", n.max(1)), Box::new(Blocked { tile: n.max(1) })),
        ("parallel-pinned".into(), Box::new(PinnedRef(pinned))),
    ];
    v.push(("parallel-global".into(), Box::new(GlobalRef)));
    v
}

/// Borrow-wrapper so a caller-owned pinned pool fits the same
/// `Box<dyn Backend>` list as the owned variants.
struct PinnedRef<'a>(&'a Parallel);

impl Backend for PinnedRef<'_> {
    fn kind(&self) -> BackendKind {
        self.0.kind()
    }
    fn stripe_rows(&self, rows: usize) -> usize {
        self.0.stripe_rows(rows)
    }
    fn for_row_stripes(
        &self,
        data: &mut [f64],
        row_len: usize,
        job: &(dyn Fn(usize, &mut [f64]) + Sync),
    ) {
        self.0.for_row_stripes(data, row_len, job)
    }
}

struct GlobalRef;

impl Backend for GlobalRef {
    fn kind(&self) -> BackendKind {
        Parallel::global().kind()
    }
    fn stripe_rows(&self, rows: usize) -> usize {
        Parallel::global().stripe_rows(rows)
    }
    fn for_row_stripes(
        &self,
        data: &mut [f64],
        row_len: usize,
        job: &(dyn Fn(usize, &mut [f64]) + Sync),
    ) {
        Parallel::global().for_row_stripes(data, row_len, job)
    }
}

#[test]
fn gram_is_bitwise_backend_invariant_for_every_kernel() {
    let pinned = Parallel::new(Arc::new(WorkPool::new(3)));
    for &(n, d) in &[(1usize, 3usize), (7, 5), (33, 8), (64, 4), (100, 16)] {
        let x = randmat(n, d, 1000 + n as u64);
        for kernel in [
            Kernel::Linear,
            Kernel::Rbf { rho: 0.35 },
            Kernel::Poly { degree: 3, c: 0.5 },
        ] {
            let reference = gram_with(&x, kernel, &Scalar);
            for (name, b) in backends(n, &pinned) {
                assert_eq!(
                    gram_with(&x, kernel, b.as_ref()),
                    reference,
                    "gram n={n} kernel={} backend={name}",
                    kernel.name()
                );
            }
        }
    }
}

#[test]
fn cross_gram_is_bitwise_backend_invariant_including_non_square() {
    let pinned = Parallel::new(Arc::new(WorkPool::new(2)));
    for &(ne, nt, d) in &[(1usize, 17usize, 4usize), (40, 7, 6), (100, 33, 5), (65, 64, 3)] {
        let xe = randmat(ne, d, 7 + ne as u64);
        let xt = randmat(nt, d, 11 + nt as u64);
        let kernel = Kernel::Rbf { rho: 0.2 };
        let reference = cross_gram_with(&xe, &xt, kernel, &Scalar);
        for (name, b) in backends(ne, &pinned) {
            assert_eq!(
                cross_gram_with(&xe, &xt, kernel, b.as_ref()),
                reference,
                "cross_gram {ne}x{nt} backend={name}"
            );
        }
    }
}

#[test]
fn accumulate_tn_is_bitwise_backend_invariant_including_non_square() {
    let pinned = Parallel::new(Arc::new(WorkPool::new(5)));
    for &(rows, ca, cb) in &[(1usize, 1usize, 1usize), (23, 6, 4), (64, 100, 3), (100, 40, 40)] {
        let a = randmat(rows, ca, 31 + rows as u64);
        let b = randmat(rows, cb, 37 + rows as u64);
        // seed the accumulator non-zero: += must agree too, not just =
        let seed_acc = randmat(ca, cb, 41);
        let mut reference = seed_acc.clone();
        accumulate_tn_with(&mut reference, &a, &b, &Scalar);
        for (name, bk) in backends(ca, &pinned) {
            let mut acc = seed_acc.clone();
            accumulate_tn_with(&mut acc, &a, &b, bk.as_ref());
            assert_eq!(acc, reference, "accumulate_tn {rows}x{ca}x{cb} backend={name}");
        }
    }
}

#[test]
fn matmul_into_is_bitwise_backend_invariant() {
    let pinned = Parallel::new(Arc::new(WorkPool::new(4)));
    for &(m, k, n) in &[(1usize, 7usize, 1usize), (17, 9, 23), (64, 64, 64), (100, 5, 33)] {
        let a = randmat(m, k, 51 + m as u64);
        let b = randmat(k, n, 53 + n as u64);
        let mut reference = Mat::zeros(m, n);
        matmul_into_with(&a, &b, &mut reference, &Scalar);
        for (name, bk) in backends(m, &pinned) {
            let mut out = Mat::zeros(m, n);
            matmul_into_with(&a, &b, &mut out, bk.as_ref());
            assert_eq!(out, reference, "matmul {m}x{k}x{n} backend={name}");
        }
    }
}

#[test]
fn cholesky_is_bitwise_backend_invariant_per_block_and_close_across_blocks() {
    let pinned = Parallel::new(Arc::new(WorkPool::new(3)));
    for &n in &[5usize, 33, 64, 100] {
        let a = spd(n, 61 + n as u64);
        let blocks = [1usize, 7, 64, n];
        let mut per_block: Vec<Mat> = Vec::new();
        for &block in &blocks {
            let reference = cholesky_with(&a, block, &Scalar).unwrap();
            for (name, b) in backends(n, &pinned) {
                let l = cholesky_with(&a, block, b.as_ref()).unwrap();
                assert_eq!(l, reference, "chol n={n} block={block} backend={name}");
            }
            per_block.push(reference);
        }
        // across block sizes the panel split reassociates the trailing
        // update, so only closeness is promised — and L L^T must still
        // reconstruct A to the same accuracy
        for (i, l) in per_block.iter().enumerate() {
            let drift = l.sub(&per_block[0]).max_abs();
            assert!(
                drift <= 1e-12 * (1.0 + per_block[0].max_abs()),
                "n={n}: blocks {} vs {} drift {drift}",
                blocks[i],
                blocks[0]
            );
            assert!(l.matmul_nt(l).sub(&a).max_abs() < 1e-9, "n={n} block={}", blocks[i]);
        }
    }
}

#[test]
fn non_spd_pivot_error_is_identical_for_every_backend_and_block() {
    // identity with one negative diagonal entry: the factorization is
    // exact up to the bad pivot, so the pivot index is deterministic
    // across blocks AND backends — everyone must report the same error
    let pinned = Parallel::new(Arc::new(WorkPool::new(2)));
    let n = 64;
    let mut a = Mat::eye(n);
    a[(41, 41)] = -1.0;
    for block in [1usize, 7, 64, n] {
        for (name, b) in backends(n, &pinned) {
            match cholesky_with(&a, block, b.as_ref()) {
                Err(CholError::NotPositiveDefinite(k)) => {
                    assert_eq!(k, 41, "block={block} backend={name}")
                }
                other => panic!("block={block} backend={name}: expected error, got {other:?}"),
            }
        }
    }
}

#[test]
fn near_singular_outcome_is_identical_across_backends_per_block() {
    // rank-1 + tiny ridge: whether the factorization squeaks through or
    // dies (and at which pivot) is decided by rounding — but for a fixed
    // block the decision must be the same on every backend, because they
    // run the same floating-point program
    let pinned = Parallel::new(Arc::new(WorkPool::new(3)));
    let n = 40;
    let v = randmat(n, 1, 71);
    let mut a = v.matmul_nt(&v);
    a.add_ridge(1e-13);
    for block in [1usize, 8, n] {
        let reference = cholesky_with(&a, block, &Scalar);
        for (name, b) in backends(n, &pinned) {
            let got = cholesky_with(&a, block, b.as_ref());
            match (&reference, &got) {
                (Ok(lr), Ok(lg)) => {
                    assert_eq!(lg, lr, "block={block} backend={name}")
                }
                (Err(er), Err(eg)) => {
                    assert_eq!(eg, er, "block={block} backend={name}")
                }
                _ => panic!(
                    "block={block} backend={name}: scalar says {:?}, got {:?}",
                    reference.as_ref().map(|_| "Ok"),
                    got.as_ref().map(|_| "Ok")
                ),
            }
        }
    }
}

#[test]
fn parallel_hammer_is_byte_identical_across_50_runs_and_pool_sizes() {
    // run-to-run determinism under concurrency churn: 50 rounds, pool
    // shrinking and growing between 1 and 8 workers, gram + cholesky
    // every round — all bits must match round 0
    let x = randmat(90, 12, 97);
    let kernel = Kernel::Rbf { rho: 0.15 };
    let mut first: Option<(Mat, Mat)> = None;
    for i in 0..50usize {
        let par = Parallel::new(Arc::new(WorkPool::new(1 + i % 8)));
        let k = gram_with(&x, kernel, &par);
        let mut a = k.clone();
        a.add_ridge(1e-3);
        let l = cholesky_with(&a, 16, &par).unwrap();
        match &first {
            None => first = Some((k, l)),
            Some((k0, l0)) => {
                assert_eq!(&k, k0, "gram drifted on round {i} (pool={})", 1 + i % 8);
                assert_eq!(&l, l0, "chol drifted on round {i} (pool={})", 1 + i % 8);
            }
        }
    }
}

// --- end to end through the model subsystem -------------------------------

fn tiny_split() -> Split {
    let mut d = by_name("mscorid").unwrap();
    d.n_classes = 4;
    d.test_per_class = 15;
    d.split(Condition::Ex10)
}

/// The `akda train` shape: exact-AKDA projection + OvR LSVM bank, built
/// under whatever `linalg::backend` is globally selected.
fn train_bank(split: &Split) -> DetectorBank {
    let hp = Hyper { rho: 0.05, c: 1.0, h: 2, ..Default::default() };
    let projection: Box<dyn Projection> = build_dr(MethodId::Akda, hp, 1e-3, None)
        .unwrap()
        .expect("akda has a DR stage")
        .fit(&split.x_train, &split.y_train, split.n_classes)
        .unwrap();
    let z = projection.project(&split.x_train);
    let svms = (0..split.n_classes)
        .map(|cls| {
            let y: Vec<f64> = split
                .y_train
                .iter()
                .map(|&l| if l == cls { 1.0 } else { -1.0 })
                .collect();
            (format!("class{cls}"), LinearSvm::train(&z, &y, LinearSvmConfig::default()))
        })
        .collect();
    DetectorBank { projection, svms }
}

#[test]
fn parallel_trained_model_scores_bitwise_like_scalar_through_save_load() {
    // the whole training pipeline — gram, cholesky solve, projection,
    // SVM bank — under --backend parallel vs --backend scalar, then
    // through the artifact codec: every byte of behavior must match.
    // (Safe to flip the global here even though tests share the process:
    // the seam is bit-for-bit, so concurrent tests cannot observe it.)
    let split = tiny_split();
    backend::set_global(BackendKind::Scalar);
    let scalar_bank = train_bank(&split);
    let scalar_scores = scalar_bank.score(&split.x_test);

    backend::set_global(BackendKind::Parallel);
    let parallel_bank = train_bank(&split);
    let parallel_scores = parallel_bank.score(&split.x_test);
    backend::set_global(BackendKind::Auto);

    assert_eq!(
        parallel_scores, scalar_scores,
        "parallel-trained bank must score bit-for-bit like scalar"
    );

    // save → load the parallel-trained bank, score again: still the
    // scalar bits (the artifact carries no backend dependence at all)
    let bytes = encode_bank(&parallel_bank, "akda").unwrap().to_bytes();
    let loaded = decode_bank(&ModelArtifact::from_bytes(&bytes).unwrap()).unwrap();
    assert_eq!(loaded.score(&split.x_test), scalar_scores);
}
