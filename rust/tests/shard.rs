//! Integration: sharded distributed training via accumulator merge (L11).
//!
//! Pins the PR's acceptance guarantees, end to end through the partial
//! `.akda` artifact codec (every shard below is serialized to bytes and
//! decoded back before it is merged — exactly what `akda train --shard` /
//! `akda merge` do across processes):
//!
//! 1. **Merge algebra** — merging shard artifacts is commutative and
//!    parenthesization-invariant *bit for bit*: every insertion order and
//!    every merge-tree shape over the same k shards produces the
//!    bit-identical merged Gram, class sums, counts, union reservoir, and
//!    published projection scores.
//! 2. **k = 1 identity** — a single-shard "distributed" train merges to
//!    bit-for-bit the unsharded streaming train, resume reservoir
//!    included.
//! 3. **Shard grid** — for k ∈ {1, 2, 3, 7}, the merged model's scores
//!    match the unsharded streaming fit and the dense in-memory fit to
//!    ≤ 1e-10.
//! 4. **Typed rejection** — mismatched landmark bases, ε, class axes,
//!    shard counts, duplicate or missing shards all fail with typed
//!    [`MergeError`]s (and tampered artifacts fail at decode), never
//!    panics, never a silently wrong merge.
//! 5. **Seed hygiene** — shards of one train draw their reservoirs from
//!    decorrelated RNG streams (the `seed ^ 0x9E37`-style correlation
//!    this PR removed stays removed).

use std::sync::Arc;

use akda::approx::FeatureMap;
use akda::da::akda_approx::AkdaApprox;
use akda::da::akda_stream::{
    BlockedProjection, MergeError, PreparedStream, TiledAccumulator,
};
use akda::da::Projection;
use akda::data::stream::{
    reservoir_sample_labeled, BlockSource, MemBlockSource, StridedBlockSource,
};
use akda::data::synthetic::{gaussian_classes, GaussianSpec};
use akda::kernels::Kernel;
use akda::linalg::Mat;
use akda::model::codec::ApproxResume;
use akda::model::shard::{basis_fingerprint, SHARD_BASIS_KEY};
use akda::model::update::{DEFAULT_RESERVOIR_CAP, DEFAULT_UPDATE_SEED, REFRESH_SAMPLE_STREAM};
use akda::model::{decode_shard, encode_shard, ModelArtifact, ShardPiece, ShardSet};
use akda::util::rng::{derive_seed, shard_seed};

const BLOCK_ROWS: usize = 64;
const LANDMARKS: usize = 16;
const N_CLASSES: usize = 3;

fn toy_data(seed: u64) -> (Mat, Vec<usize>) {
    gaussian_classes(&GaussianSpec {
        n_classes: N_CLASSES,
        n_per_class: vec![40; N_CLASSES],
        dim: 5,
        class_sep: 2.0,
        noise: 0.8,
        modes_per_class: 1,
        seed,
    })
}

fn approx() -> AkdaApprox {
    AkdaApprox::nystrom(Kernel::Rbf { rho: 0.5 }, LANDMARKS)
}

/// The map every shard of one train shares, fitted from the full stream —
/// deterministic per seed, so independent workers derive it identically.
fn shared_map(ap: &AkdaApprox, x: &Mat, y: &[usize]) -> Arc<dyn FeatureMap> {
    let mut src = MemBlockSource::new(x, y, BLOCK_ROWS);
    Arc::from(ap.build_map_stream(&mut src).unwrap())
}

/// One worker's shard train: accumulate stride `index` of the stream,
/// then round-trip the piece through the partial-artifact codec bytes.
fn shard_piece(
    ap: &AkdaApprox,
    map: &Arc<dyn FeatureMap>,
    x: &Mat,
    y: &[usize],
    index: usize,
    count: usize,
) -> ShardPiece {
    let mut src =
        StridedBlockSource::new(MemBlockSource::new(x, y, BLOCK_ROWS), index, count).unwrap();
    let mut acc = TiledAccumulator::new(map.dim());
    src.reset().unwrap();
    while let Some(block) = src.next_block().unwrap() {
        let phi = map.transform(&block.x);
        acc.absorb(&phi, &block.labels).unwrap();
    }
    let agg = acc.into_aggregates(N_CLASSES).unwrap();
    let (reservoir, reservoir_labels, seen) = reservoir_sample_labeled(
        &mut src,
        DEFAULT_RESERVOIR_CAP,
        shard_seed(DEFAULT_UPDATE_SEED, index, count),
    )
    .unwrap();
    let piece = ShardPiece {
        index,
        count,
        basis: basis_fingerprint(map.as_ref()).unwrap(),
        block_rows: BLOCK_ROWS,
        map: Arc::clone(map),
        resume: ApproxResume {
            gram: agg.gram,
            class_sums: agg.class_sums,
            counts: agg.counts,
            reservoir,
            reservoir_labels,
            seen,
            eps: ap.eps,
        },
        meta: Default::default(),
    };
    // through the wire: serialize, checksum-verify, decode — merge input
    // is always a decoded artifact, never an in-process shortcut
    let bytes = encode_shard(&piece).unwrap().to_bytes();
    decode_shard(&ModelArtifact::from_bytes(&bytes).unwrap()).unwrap()
}

/// Finalize a shard set and publish its projection scores on `x_test`.
fn merged_scores(set: ShardSet, x_test: &Mat) -> (Mat, Mat, Vec<usize>, Mat) {
    let merged = set.finalize(DEFAULT_RESERVOIR_CAP).unwrap();
    let (res_x, _) = merged.reservoir.snapshot().unwrap();
    let gram = merged.aggregates.gram.clone();
    let counts = merged.aggregates.counts.clone();
    let prep = PreparedStream::from_aggregates(
        Arc::clone(&merged.map),
        merged.aggregates,
        merged.eps,
        akda::linalg::chol::DEFAULT_BLOCK,
    )
    .unwrap();
    let w = prep.solve_w_multiclass().unwrap();
    let proj = BlockedProjection {
        map: Arc::clone(&prep.map),
        w,
        block_rows: BLOCK_ROWS,
    };
    (proj.project(x_test), gram, counts, res_x)
}

fn assert_bit_identical(a: &Mat, b: &Mat, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape");
    assert!(
        a.sub(b).max_abs() == 0.0,
        "{what} must be bit-for-bit identical (max |Δ| = {:e})",
        a.sub(b).max_abs()
    );
}

/// Acceptance 1: every insertion order and merge-tree shape over the same
/// shard artifacts publishes the bit-identical model.
#[test]
fn merge_is_commutative_and_tree_invariant_bit_for_bit() {
    let (x, y) = toy_data(11);
    let (xt, _) = toy_data(12);
    let ap = approx();
    let map = shared_map(&ap, &x, &y);
    let k = 3;
    let pieces =
        |order: &[usize]| -> Vec<ShardPiece> {
            order.iter().map(|&i| shard_piece(&ap, &map, &x, &y, i, k)).collect()
        };

    // left-to-right insertion, ascending
    let mut forward = ShardSet::new();
    for p in pieces(&[0, 1, 2]) {
        forward.insert(p).unwrap();
    }
    // reversed insertion order — merge(A,B) == merge(B,A)
    let mut reversed = ShardSet::new();
    for p in pieces(&[2, 1, 0]) {
        reversed.insert(p).unwrap();
    }
    // pairwise reduction, scrambled: (2 ∪ 0) ∪ (1)
    let mut tree = ShardSet::new();
    let mut left = ShardSet::new();
    for p in pieces(&[2, 0]) {
        left.insert(p).unwrap();
    }
    let mut right = ShardSet::new();
    right.insert(pieces(&[1]).pop().unwrap()).unwrap();
    tree.merge(left).unwrap();
    tree.merge(right).unwrap();

    let (za, ga, ca, ra) = merged_scores(forward, &xt);
    let (zb, gb, cb, rb) = merged_scores(reversed, &xt);
    let (zc, gc, cc, rc) = merged_scores(tree, &xt);
    assert_bit_identical(&ga, &gb, "merged Gram (insertion order)");
    assert_bit_identical(&ga, &gc, "merged Gram (tree shape)");
    assert_eq!(ca, cb);
    assert_eq!(ca, cc);
    assert_bit_identical(&ra, &rb, "union reservoir (insertion order)");
    assert_bit_identical(&ra, &rc, "union reservoir (tree shape)");
    assert_bit_identical(&za, &zb, "published scores (insertion order)");
    assert_bit_identical(&za, &zc, "published scores (tree shape)");
}

/// Acceptance 2: k = 1 sharded training merges to bit-for-bit the
/// unsharded streaming train — scores AND resume reservoir.
#[test]
fn single_shard_train_is_bitwise_the_unsharded_train() {
    let (x, y) = toy_data(21);
    let (xt, _) = toy_data(22);
    let ap = approx();

    // unsharded reference: the exact `akda train --stream` path
    let mut src = MemBlockSource::new(&x, &y, BLOCK_ROWS);
    let prep = ap.prepare_stream(&mut src).unwrap();
    let w = prep.solve_w_multiclass().unwrap();
    let z_ref = BlockedProjection {
        map: Arc::clone(&prep.map),
        w,
        block_rows: BLOCK_ROWS,
    }
    .project(&xt);
    let mut res_src = MemBlockSource::new(&x, &y, BLOCK_ROWS);
    let (res_ref, labels_ref, seen_ref) =
        reservoir_sample_labeled(&mut res_src, DEFAULT_RESERVOIR_CAP, DEFAULT_UPDATE_SEED)
            .unwrap();

    // the k = 1 "distributed" train
    let map = shared_map(&ap, &x, &y);
    let piece = shard_piece(&ap, &map, &x, &y, 0, 1);
    assert_eq!(piece.resume.seen, seen_ref);
    let mut set = ShardSet::new();
    set.insert(piece).unwrap();
    let merged = set.finalize(DEFAULT_RESERVOIR_CAP).unwrap();
    let (res_x, res_l) = merged.reservoir.snapshot().unwrap();
    assert_bit_identical(&res_x, &res_ref, "k=1 resume reservoir");
    assert_eq!(res_l, labels_ref);
    let prep1 = PreparedStream::from_aggregates(
        Arc::clone(&merged.map),
        merged.aggregates,
        merged.eps,
        akda::linalg::chol::DEFAULT_BLOCK,
    )
    .unwrap();
    let w1 = prep1.solve_w_multiclass().unwrap();
    let z1 = BlockedProjection {
        map: Arc::clone(&prep1.map),
        w: w1,
        block_rows: BLOCK_ROWS,
    }
    .project(&xt);
    assert_bit_identical(&z1, &z_ref, "k=1 published scores");
}

/// Acceptance 3: the shard grid k ∈ {1, 2, 3, 7} reproduces both the
/// unsharded streaming fit and the dense in-memory fit to ≤ 1e-10.
#[test]
fn shard_grid_matches_streaming_and_dense_fits() {
    let (x, y) = toy_data(31);
    let (xt, _) = toy_data(32);
    let ap = approx();

    let mut src = MemBlockSource::new(&x, &y, BLOCK_ROWS);
    let prep = ap.prepare_stream(&mut src).unwrap();
    let w = prep.solve_w_multiclass().unwrap();
    let z_stream = BlockedProjection {
        map: Arc::clone(&prep.map),
        w,
        block_rows: BLOCK_ROWS,
    }
    .project(&xt);
    // dense in-memory fit: same approximation, no streaming at all
    let z_dense = ap
        .prepare(&x)
        .unwrap()
        .fit(&y, N_CLASSES)
        .unwrap()
        .project(&xt);
    let scale = 1.0 + z_stream.max_abs();

    let map = shared_map(&ap, &x, &y);
    for k in [1usize, 2, 3, 7] {
        let mut set = ShardSet::new();
        for i in 0..k {
            set.insert(shard_piece(&ap, &map, &x, &y, i, k)).unwrap();
        }
        let (z, _, counts, _) = merged_scores(set, &xt);
        assert_eq!(counts.iter().sum::<usize>(), x.rows(), "k={k}: row conservation");
        let vs_stream = z.sub(&z_stream).max_abs();
        let vs_dense = z.sub(&z_dense).max_abs();
        assert!(
            vs_stream <= 1e-10 * scale,
            "k={k}: merged scores drift {vs_stream:e} from the streaming fit"
        );
        assert!(
            vs_dense <= 1e-10 * scale,
            "k={k}: merged scores drift {vs_dense:e} from the dense fit"
        );
    }
}

/// Acceptance 4: incompatible or damaged shards are rejected with typed
/// errors — at decode for tampering, at insert for algebra violations.
#[test]
fn incompatible_and_tampered_shards_are_rejected() {
    let (x, y) = toy_data(41);
    let ap = approx();
    let map = shared_map(&ap, &x, &y);
    let mut set = ShardSet::new();
    set.insert(shard_piece(&ap, &map, &x, &y, 0, 2)).unwrap();

    // duplicate stride index
    match set.insert(shard_piece(&ap, &map, &x, &y, 0, 2)) {
        Err(MergeError::DuplicateShard { index: 0 }) => {}
        other => panic!("want DuplicateShard, got {other:?}"),
    }
    // shard of a different k
    match set.insert(shard_piece(&ap, &map, &x, &y, 1, 3)) {
        Err(MergeError::ShardCountMismatch { left: 2, right: 3 }) => {}
        other => panic!("want ShardCountMismatch, got {other:?}"),
    }
    // different landmark budget → different feature dimension
    let mut fat = AkdaApprox::nystrom(Kernel::Rbf { rho: 0.5 }, 2 * LANDMARKS);
    fat.eps = ap.eps;
    let fat_map = shared_map(&fat, &x, &y);
    match set.insert(shard_piece(&fat, &fat_map, &x, &y, 1, 2)) {
        Err(MergeError::DimMismatch { .. }) => {}
        other => panic!("want DimMismatch, got {other:?}"),
    }
    // same dimensions, different landmark basis (another train's map)
    let mut other_ap = approx();
    other_ap.seed = ap.seed.wrapping_add(1);
    let other_map = shared_map(&other_ap, &x, &y);
    match set.insert(shard_piece(&other_ap, &other_map, &x, &y, 1, 2)) {
        Err(MergeError::BasisMismatch { .. }) => {}
        other => panic!("want BasisMismatch, got {other:?}"),
    }
    // different ridge ε
    let mut off = shard_piece(&ap, &map, &x, &y, 1, 2);
    off.resume.eps = ap.eps * 2.0;
    match set.insert(off) {
        Err(MergeError::EpsMismatch { .. }) => {}
        other => panic!("want EpsMismatch, got {other:?}"),
    }
    // different class axis (padded to a different declared C)
    let mut narrow = shard_piece(&ap, &map, &x, &y, 1, 2);
    narrow.resume.class_sums =
        Mat::from_fn(narrow.resume.gram.rows(), N_CLASSES + 1, |_, _| 0.0);
    match set.insert(narrow) {
        Err(MergeError::ClassMismatch { .. }) => {}
        other => panic!("want ClassMismatch, got {other:?}"),
    }
    // incomplete set cannot finalize
    match set.finalize(DEFAULT_RESERVOIR_CAP).unwrap_err().downcast::<MergeError>() {
        Ok(MergeError::Incomplete { have: 1, want: 2 }) => {}
        other => panic!("want Incomplete, got {other:?}"),
    }
    // a tampered artifact (spliced basis meta) dies at decode, not merge
    let good = shard_piece(&ap, &map, &x, &y, 1, 2);
    let mut art = encode_shard(&good).unwrap();
    art.set_meta(SHARD_BASIS_KEY, format!("{:016x}", good.basis ^ 0xdead));
    let err = decode_shard(&art).unwrap_err().to_string();
    assert!(err.contains("fingerprint"), "decode error names the check: {err}");
}

/// Acceptance 5 (seed-derivation regression): shards of one base seed
/// sample decorrelated reservoirs — no two shards of any k, nor the same
/// index across different k, share a reservoir.
#[test]
fn shard_reservoirs_are_decorrelated_across_shards() {
    let (x, y) = toy_data(51);
    let ap = approx();
    let map = shared_map(&ap, &x, &y);
    let k = 3;
    let pieces: Vec<ShardPiece> =
        (0..k).map(|i| shard_piece(&ap, &map, &x, &y, i, k)).collect();
    for a in 0..k {
        for b in (a + 1)..k {
            let (ra, rb) = (&pieces[a].resume.reservoir, &pieces[b].resume.reservoir);
            let differs = ra.shape() != rb.shape() || ra.sub(rb).max_abs() > 0.0;
            assert!(differs, "shards {a} and {b} sampled an identical reservoir");
        }
    }
    // the derived seeds themselves never collide across shard layouts
    let mut seeds: Vec<u64> = vec![shard_seed(DEFAULT_UPDATE_SEED, 0, 1)];
    for count in [2usize, 3, 7] {
        for index in 0..count {
            seeds.push(shard_seed(DEFAULT_UPDATE_SEED, index, count));
        }
    }
    let n = seeds.len();
    seeds.sort_unstable();
    seeds.dedup();
    assert_eq!(seeds.len(), n, "shard seeds must be unique per (index, count)");

    // and decorrelated where it matters: sampling the SAME stream with a
    // small cap, different derived seeds must make different draws (the
    // old `seed ^ 0x9E37` derivation could collapse to correlated
    // streams; `derive_seed` runs the tag through a splitmix finalizer)
    let sample = |seed: u64| -> Mat {
        let mut src = MemBlockSource::new(&x, &y, BLOCK_ROWS);
        reservoir_sample_labeled(&mut src, 16, seed).unwrap().0
    };
    let base = sample(DEFAULT_UPDATE_SEED);
    let refresh = sample(derive_seed(DEFAULT_UPDATE_SEED, REFRESH_SAMPLE_STREAM));
    assert!(
        base.sub(&refresh).max_abs() > 0.0,
        "the refresh sample stream must not replay the base stream"
    );
    let s0 = sample(shard_seed(DEFAULT_UPDATE_SEED, 0, 3));
    let s1 = sample(shard_seed(DEFAULT_UPDATE_SEED, 1, 3));
    let s2 = sample(shard_seed(DEFAULT_UPDATE_SEED, 2, 3));
    assert!(s0.sub(&s1).max_abs() > 0.0, "shards 0/1 drew identical samples");
    assert!(s0.sub(&s2).max_abs() > 0.0, "shards 0/2 drew identical samples");
    assert!(s1.sub(&s2).max_abs() > 0.0, "shards 1/2 drew identical samples");
}
