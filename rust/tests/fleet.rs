//! Integration: multi-tenant fleet serving + the drop-directory
//! auto-update daemon (L6).
//!
//! Pins the PR's acceptance guarantees:
//!
//! 1. **Routing** — one `FleetService` serves ≥ 2 model names from one
//!    registry over one shared pool, answering by model id; unknown ids
//!    and wrong feature widths come back as protocol errors (never a
//!    panic) and leave the real tenants undisturbed.
//! 2. **Independent hot swaps** — republishing one tenant hot-swaps only
//!    that tenant while live traffic to the others keeps being answered.
//! 3. **GC shield** — a fleet's serve markers auto-protect every
//!    tenant's served version from `Registry::prune`.
//! 4. **Daemon hygiene** — the drop-dir watcher consumes settled
//!    `NAME.csv` files into published updates, quarantines malformed
//!    ones, and never half-reads a file still being written.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::Duration;

use akda::coordinator::fleet::{DropDirWatcher, DropEvent, FleetError};
use akda::coordinator::{DetectorBank, FleetOptions, FleetService, UpdateDaemon};
use akda::da::akda::Akda;
use akda::data::synthetic::{gaussian_classes, GaussianSpec};
use akda::kernels::Kernel;
use akda::linalg::Mat;
use akda::model::codec::{encode_resume, ExactResume};
use akda::model::update::train_svm_bank;
use akda::model::{
    apply_update, encode_bank, ModelArtifact, ModelManifest, ModelRegistry, ResumeState,
    UpdateOptions,
};

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("akda_fleet_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Exact-AKDA bank + artifact with embedded resume state — the same shape
/// `akda train --method akda` publishes (updatable by the daemon).
fn trained_artifact(
    dim: usize,
    n_classes: usize,
    seed: u64,
) -> (Mat, Vec<usize>, ModelArtifact) {
    let (x, labels) = gaussian_classes(&GaussianSpec {
        n_classes,
        n_per_class: vec![12; n_classes],
        dim,
        class_sep: 2.5,
        noise: 0.6,
        modes_per_class: 1,
        seed,
    });
    let akda_cfg = Akda::new(Kernel::Rbf { rho: 0.4 });
    let (proj, chol_l) = akda_cfg.fit_with_factor(&x, &labels, n_classes).unwrap();
    let z = proj.project(&x);
    let svms = train_svm_bank(&z, &labels, n_classes);
    let bank = DetectorBank { projection: Box::new(proj), svms };
    let mut art = encode_bank(&bank, "akda").unwrap();
    encode_resume(
        &mut art,
        &ResumeState::Exact(ExactResume {
            chol_l,
            labels: labels.clone(),
            eps: akda_cfg.eps,
            n_classes,
        }),
    )
    .unwrap();
    (x, labels, art)
}

fn manifest(dim: usize, n_classes: usize) -> ModelManifest {
    ModelManifest {
        method: "akda".into(),
        n_classes,
        input_dim: dim,
        ..Default::default()
    }
}

/// Acceptance: one process, two tenants with different shapes, routed by
/// id; unknown ids / wrong widths are protocol errors, and the serve
/// markers shield the served versions from prune.
#[test]
fn fleet_routes_by_id_rejects_unknown_ids_and_shields_gc() {
    let root = tmpdir("routing");
    let registry = ModelRegistry::open(&root);
    // tenant "aa": 6 features / 3 classes; tenant "bb": 5 features / 2
    let (xa, _, art_a) = trained_artifact(6, 3, 1);
    let (xb, _, art_b) = trained_artifact(5, 2, 2);
    registry.publish("aa", &art_a, &manifest(6, 3)).unwrap();
    registry.publish("bb", &art_b, &manifest(5, 2)).unwrap();

    let svc = FleetService::start(&registry, FleetOptions::default()).unwrap();
    let client = svc.client();
    assert_eq!(client.models(), vec!["aa".to_string(), "bb".to_string()]);
    assert_eq!(svc.served_versions(), vec![("aa".into(), 1), ("bb".into(), 1)]);

    // routing: each tenant answers with ITS class count
    let sa = client.score("aa", xa.row(0).to_vec()).unwrap();
    let sb = client.score("bb", xb.row(0).to_vec()).unwrap();
    assert_eq!((sa.len(), sb.len()), (3, 2));
    assert!(sa.iter().chain(&sb).all(|s| s.is_finite()));

    // protocol errors, not panics — and the service keeps answering after
    match client.score("nope", vec![0.0; 6]) {
        Err(FleetError::UnknownModel { model, known }) => {
            assert_eq!(model, "nope");
            assert_eq!(known, vec!["aa".to_string(), "bb".to_string()]);
        }
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    match client.score("bb", vec![0.0; 6]) {
        Err(FleetError::WrongDim { expected, got, .. }) => {
            assert_eq!((expected, got), (5, 6));
        }
        other => panic!("expected WrongDim, got {other:?}"),
    }
    assert_eq!(client.score("aa", xa.row(1).to_vec()).unwrap().len(), 3);

    // concurrent mixed-tenant load drains through the one shared pool
    std::thread::scope(|s| {
        for i in 0..8 {
            let client = client.clone();
            let row_a = xa.row(i).to_vec();
            let row_b = xb.row(i).to_vec();
            s.spawn(move || {
                assert_eq!(client.score("aa", row_a).unwrap().len(), 3);
                assert_eq!(client.score("bb", row_b).unwrap().len(), 2);
            });
        }
    });
    let stats = svc.stats();
    assert!(stats.requests >= 19, "stats: {stats:?}");
    assert!(stats.per_tenant["aa"] >= 10 && stats.per_tenant["bb"] >= 9, "{stats:?}");
    assert_eq!(stats.rejected, 2, "both protocol rejections are counted: {stats:?}");

    // GC shield: "aa" publishes v2 but the fleet (no watcher) serves v1 —
    // prune must auto-protect the marked served version
    registry.publish("aa", &art_a, &manifest(6, 3)).unwrap();
    assert_eq!(registry.served_versions("aa").unwrap(), vec![1]);
    assert!(registry.prune("aa", 1, None).unwrap().is_empty());
    assert_eq!(registry.versions("aa").unwrap(), vec![1, 2]);
    drop(client); // all clients must go first: the dispatcher drains on close
    drop(svc); // markers released with the fleet
    assert_eq!(registry.served_versions("aa").unwrap(), Vec::<u32>::new());
    assert_eq!(registry.prune("aa", 1, None).unwrap(), vec![1]);
    let _ = std::fs::remove_dir_all(&root);
}

/// Acceptance: a daemon-style republish of one tenant hot-swaps exactly
/// that tenant while live traffic on the other keeps being answered.
#[test]
fn hot_swapping_one_tenant_does_not_block_the_others() {
    let root = tmpdir("swap");
    let registry = ModelRegistry::open(&root);
    let (xa, _, art_a) = trained_artifact(6, 3, 3);
    let (xb, _, art_b) = trained_artifact(6, 2, 4);
    registry.publish("aa", &art_a, &manifest(6, 3)).unwrap();
    registry.publish("bb", &art_b, &manifest(6, 2)).unwrap();

    let svc = FleetService::start(
        &registry,
        FleetOptions { watch: Some(Duration::from_millis(10)), ..Default::default() },
    )
    .unwrap();
    let client = svc.client();
    let before = client.score("aa", xa.row(0).to_vec()).unwrap();

    let stop = AtomicBool::new(false);
    let answered = AtomicUsize::new(0);
    std::thread::scope(|s| {
        // continuous live traffic on tenant "bb" for the whole swap window
        for w in 0..2 {
            let client = client.clone();
            let (stop, answered, xb) = (&stop, &answered, &xb);
            s.spawn(move || {
                let mut i = w;
                while !stop.load(Ordering::Relaxed) {
                    let row = xb.row(i % xb.rows()).to_vec();
                    let scores = client.score("bb", row).expect("bb must keep answering");
                    assert_eq!(scores.len(), 2);
                    answered.fetch_add(1, Ordering::Relaxed);
                    i += 2;
                }
            });
        }

        // grow + republish tenant "aa" (what the daemon does on a drop)
        let (x2, y2) = gaussian_classes(&GaussianSpec {
            n_classes: 3,
            n_per_class: vec![6; 3],
            dim: 6,
            class_sep: 2.5,
            noise: 0.6,
            modes_per_class: 1,
            seed: 13,
        });
        let (_, artifact) = registry.load_artifact("aa").unwrap();
        let (_, new_art, report) =
            apply_update(&artifact, &x2, &y2, &UpdateOptions::default()).unwrap();
        assert_eq!(report.kind, "exact-bordered");
        registry.publish("aa", &new_art, &manifest(6, 3)).unwrap();

        // bounded wait for the single watcher to swap tenant "aa" in
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        while svc.served_version("aa") != Some(2)
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        stop.store(true, Ordering::Relaxed);
    });

    assert_eq!(svc.served_version("aa"), Some(2), "aa never hot-swapped");
    assert_eq!(svc.served_version("bb"), Some(1), "bb must be untouched");
    assert_eq!(svc.swaps(), 1);
    assert!(answered.load(Ordering::Relaxed) > 0, "bb traffic must flow throughout");
    // the swap changed what "aa" answers, and the marker followed it
    let after = client.score("aa", xa.row(0).to_vec()).unwrap();
    assert_ne!(before, after, "the republished model must actually serve");
    assert_eq!(registry.served_versions("aa").unwrap(), vec![2]);
    drop(client); // all clients must go first: the dispatcher drains on close
    drop(svc);
    let _ = std::fs::remove_dir_all(&root);
}

/// Acceptance: the drop-dir watcher ignores files until they settle,
/// quarantines malformed / mistargeted ones, and publishes good ones.
#[test]
fn drop_watcher_settles_quarantines_and_updates() {
    let root = tmpdir("dropdir");
    let registry = ModelRegistry::open(root.join("registry"));
    let drop_dir = root.join("drop");
    std::fs::create_dir_all(&drop_dir).unwrap();
    let (x, labels, art) = trained_artifact(6, 3, 5);
    registry.publish("m", &art, &manifest(6, 3)).unwrap();
    let mut watcher = DropDirWatcher::new(registry.clone(), &drop_dir, UpdateOptions::default());

    // a drop targeting a model that does not exist: settle, then quarantine
    std::fs::write(drop_dir.join("ghost.csv"), "0,1.0,2.0,3.0,4.0,5.0,6.0\n").unwrap();
    assert!(matches!(watcher.poll().as_slice(), [DropEvent::Waiting { .. }]));
    match watcher.poll().as_slice() {
        [DropEvent::Rejected { file, reason }] => {
            assert!(file.ends_with("ghost.csv"));
            assert!(reason.contains("ghost"), "{reason}");
        }
        other => panic!("expected a rejection, got {other:?}"),
    }
    assert!(drop_dir.join("ghost.csv.rejected").exists());
    assert!(!drop_dir.join("ghost.csv").exists());
    // the reason sidecar makes the rejection diagnosable post-hoc
    let sidecar = std::fs::read_to_string(drop_dir.join("ghost.csv.rejected.reason")).unwrap();
    assert!(sidecar.contains("ghost"), "sidecar must carry the reason: {sidecar}");

    // malformed rows: quarantined, the model is untouched
    std::fs::write(drop_dir.join("m.csv"), "0,1.0,not-a-number\n").unwrap();
    watcher.poll(); // settle sighting
    assert!(matches!(watcher.poll().as_slice(), [DropEvent::Rejected { .. }]));
    assert!(drop_dir.join("m.csv.rejected").exists());
    assert_eq!(registry.latest("m").unwrap().version, 1);

    // a file still being written is never consumed: every poll that sees
    // a changed (size, mtime) starts the settle clock over
    let rows = |r: std::ops::Range<usize>| -> String {
        r.map(|i| {
            let feats: Vec<String> = (0..6).map(|c| x[(i, c)].to_string()).collect();
            format!("{},{}", labels[i], feats.join(","))
        })
        .collect::<Vec<_>>()
        .join("\n")
            + "\n"
    };
    std::fs::write(drop_dir.join("m.csv"), rows(0..6)).unwrap();
    assert!(matches!(watcher.poll().as_slice(), [DropEvent::Waiting { .. }]));
    // the writer appends more rows before the next poll
    std::fs::write(drop_dir.join("m.csv"), rows(0..12)).unwrap();
    assert!(
        matches!(watcher.poll().as_slice(), [DropEvent::Waiting { .. }]),
        "a changed file must restart the settle clock"
    );
    // now stable: consumed, updated, republished, file removed
    match watcher.poll().as_slice() {
        [DropEvent::Updated { model, version, .. }] => {
            assert_eq!((model.as_str(), *version), ("m", 2));
        }
        other => panic!("expected an update, got {other:?}"),
    }
    assert!(!drop_dir.join("m.csv").exists());
    let latest = registry.latest("m").unwrap();
    assert_eq!(latest.version, 2);
    assert_eq!(latest.manifest.updated_from, Some("m@1".to_string()));
    let _ = std::fs::remove_dir_all(&root);
}

/// The daemon thread end to end: drop a CSV, watch the registry grow —
/// and a fleet watcher pick the new version up — without any manual step.
#[test]
fn daemon_publishes_and_the_fleet_hot_swaps() {
    let root = tmpdir("daemon");
    let registry = ModelRegistry::open(root.join("registry"));
    let drop_dir = root.join("drop");
    std::fs::create_dir_all(&drop_dir).unwrap();
    let (_, _, art) = trained_artifact(6, 2, 6);
    registry.publish("m", &art, &manifest(6, 2)).unwrap();

    let svc = FleetService::start(
        &registry,
        FleetOptions { watch: Some(Duration::from_millis(10)), ..Default::default() },
    )
    .unwrap();
    let daemon = UpdateDaemon::start(
        registry.clone(),
        &drop_dir,
        Duration::from_millis(10),
        UpdateOptions::default(),
    );

    // new labeled rows arrive as a drop file (same shape, fresh seed)
    let (x2, y2) = gaussian_classes(&GaussianSpec {
        n_classes: 2,
        n_per_class: vec![5; 2],
        dim: 6,
        class_sep: 2.5,
        noise: 0.6,
        modes_per_class: 1,
        seed: 16,
    });
    akda::data::csv::save_labeled(&drop_dir.join("m.csv"), &x2, &y2).unwrap();

    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    while (daemon.updates() == 0 || svc.served_version("m") != Some(2))
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(daemon.updates(), 1, "the daemon never published the drop");
    assert_eq!(daemon.rejects(), 0);
    assert_eq!(registry.latest("m").unwrap().version, 2);
    assert_eq!(svc.served_version("m"), Some(2), "the fleet never swapped v2 in");
    assert!(!drop_dir.join("m.csv").exists(), "consumed drops are removed");
    drop(daemon);
    drop(svc);
    let _ = std::fs::remove_dir_all(&root);
}

/// Acceptance (L11 serving proof): multiple serving "processes" — separate
/// `FleetService`s, each with its own registry handle, as `N × akda serve
/// --fleet` would be — share ONE registry under continuous traffic while a
/// third actor publishes new versions and prunes old ones. Every watching
/// reader hot-swaps every publish, a pinned reader's version is shielded
/// from prune by its serve marker (no reader ever serves a deleted
/// version), and no request fails mid-swap or mid-prune.
#[test]
fn fleet_processes_sharing_a_registry_survive_publish_and_prune() {
    let root = tmpdir("multireader");
    let registry = ModelRegistry::open(&root);
    let (x, _, art) = trained_artifact(6, 3, 21);
    registry.publish("m", &art, &manifest(6, 3)).unwrap();

    let watching = || FleetOptions {
        watch: Some(Duration::from_millis(10)),
        ..Default::default()
    };
    let fleet_a = FleetService::start(&ModelRegistry::open(&root), watching()).unwrap();
    let fleet_b = FleetService::start(&ModelRegistry::open(&root), watching()).unwrap();
    // a third reader with no watcher: pinned to v1 for the whole test
    let pinned = FleetService::start(
        &ModelRegistry::open(&root),
        FleetOptions { watch: None, ..Default::default() },
    )
    .unwrap();

    let stop = AtomicBool::new(false);
    let answered = [AtomicUsize::new(0), AtomicUsize::new(0), AtomicUsize::new(0)];
    std::thread::scope(|s| {
        // continuous traffic through every reader for the whole window
        for (i, svc) in [&fleet_a, &fleet_b, &pinned].into_iter().enumerate() {
            let client = svc.client();
            let (stop, answered, x) = (&stop, &answered[i], &x);
            s.spawn(move || {
                let mut r = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let scores = client
                        .score("m", x.row(r % x.rows()).to_vec())
                        .expect("readers must keep answering through publish+prune");
                    assert_eq!(scores.len(), 3);
                    answered.fetch_add(1, Ordering::Relaxed);
                    r += 1;
                }
            });
        }

        // the "trainer": two republishes, each picked up by BOTH watchers
        let wait_both = |v: u32| {
            let deadline = std::time::Instant::now() + Duration::from_secs(30);
            while (fleet_a.served_version("m") != Some(v)
                || fleet_b.served_version("m") != Some(v))
                && std::time::Instant::now() < deadline
            {
                std::thread::sleep(Duration::from_millis(5));
            }
        };
        registry.publish("m", &art, &manifest(6, 3)).unwrap();
        wait_both(2);
        registry.publish("m", &art, &manifest(6, 3)).unwrap();
        wait_both(3);

        // GC mid-traffic: v1 is still served by the pinned reader, so its
        // marker shields it; v2 is served by nobody and is deleted. The
        // watcher re-points a reader's serve marker just AFTER the swap
        // becomes visible, so wait for the lease files too, not only the
        // served versions, before pruning
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        while registry.served_versions("m").unwrap().contains(&2)
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        let deleted = registry.prune("m", 1, None).unwrap();
        assert_eq!(deleted, vec![2], "only the unserved version may go");
        assert_eq!(registry.versions("m").unwrap(), vec![1, 3]);
        // the pinned reader keeps serving its protected v1 after the GC
        let scores = pinned.client().score("m", x.row(0).to_vec()).unwrap();
        assert_eq!(scores.len(), 3);
        stop.store(true, Ordering::Relaxed);
    });

    assert_eq!(fleet_a.served_version("m"), Some(3), "reader A never caught up");
    assert_eq!(fleet_b.served_version("m"), Some(3), "reader B never caught up");
    assert_eq!(pinned.served_version("m"), Some(1), "no watcher: stays pinned");
    assert!(fleet_a.swaps() >= 2 && fleet_b.swaps() >= 2);
    for count in &answered {
        assert!(count.load(Ordering::Relaxed) > 0, "every reader carried traffic");
    }
    let marked = registry.served_versions("m").unwrap();
    assert!(marked.contains(&1) && marked.contains(&3), "markers: {marked:?}");
    // releasing the pinned reader releases v1 for the next GC pass
    drop(pinned);
    assert_eq!(registry.prune("m", 1, None).unwrap(), vec![1]);
    assert_eq!(registry.versions("m").unwrap(), vec![3]);
    drop(fleet_a);
    drop(fleet_b);
    let _ = std::fs::remove_dir_all(&root);
}
