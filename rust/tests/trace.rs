//! Integration: end-to-end request tracing across the akda-wire edge
//! (L9) — `NetServer` + `TraceSink` + the client-side echo.
//!
//! Pins the PR's acceptance guarantees:
//!
//! 1. **Identity** — client-minted trace ids survive the wire round
//!    trip bit-for-bit into the server's `akda-trace/1` sink records.
//! 2. **Physics** — the echoed per-stage durations are the five hop
//!    stages in order, and their sum never exceeds the client-observed
//!    RTT (the stages are sequential, non-overlapping segments of the
//!    server-side residency).
//! 3. **Policy** — `--trace-slow-ms 0` captures every request, while
//!    `--trace-sample N` writes exactly every Nth record.
//! 4. **Sheds** — an overloaded ingress writes a terminal `net/queue`
//!    record with `shed=true` and exactly two stages, one per shed the
//!    client observed.
//! 5. **Compatibility** — pre-extension (untraced) ScoreRequest bytes
//!    still decode and score bit-for-bit against the in-process fleet.

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use akda::coordinator::net::{NetClient, NetOptions, NetReply, NetServer};
use akda::coordinator::wire::{encode, ErrorCode, Frame};
use akda::coordinator::{DetectorBank, FleetOptions, FleetService};
use akda::da::akda::Akda;
use akda::da::{DrMethod, Projection};
use akda::data::synthetic::{gaussian_classes, GaussianSpec};
use akda::kernels::Kernel;
use akda::linalg::Mat;
use akda::model::update::train_svm_bank;
use akda::model::{encode_bank, ModelArtifact, ModelManifest, ModelRegistry};
use akda::obs::trace::{parse_line, STAGES};
use akda::obs::{TraceIdGen, TraceSink};

const RECV_TIMEOUT: Duration = Duration::from_secs(30);

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("akda_trace_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Train one publishable tenant; returns its rows (for request payloads)
/// and the artifact.
fn tenant(dim: usize, n_classes: usize, seed: u64) -> (Mat, ModelArtifact) {
    let (x, labels) = gaussian_classes(&GaussianSpec {
        n_classes,
        n_per_class: vec![14; n_classes],
        dim,
        class_sep: 2.5,
        noise: 0.6,
        modes_per_class: 1,
        seed,
    });
    let akda_cfg = Akda::new(Kernel::Rbf { rho: 0.4 });
    let proj = akda_cfg.fit(&x, &labels, n_classes).expect("fit");
    let z = proj.project(&x);
    let svms = train_svm_bank(&z, &labels, n_classes);
    let bank = DetectorBank { projection: proj, svms };
    let art = encode_bank(&bank, "akda").expect("encode");
    (x, art)
}

/// Registry with one tenant `ta` (6 features / 3 classes) plus its rows.
fn one_tenant_registry(tag: &str, seed: u64) -> (PathBuf, ModelRegistry, Mat) {
    let root = tmpdir(tag);
    let registry = ModelRegistry::open(&root);
    let (x, art) = tenant(6, 3, seed);
    let mf = ModelManifest {
        method: "akda".into(),
        n_classes: 3,
        input_dim: 6,
        ..Default::default()
    };
    registry.publish("ta", &art, &mf).unwrap();
    (root, registry, x)
}

fn connect(server: &NetServer) -> NetClient {
    NetClient::connect(server.local_addr(), RECV_TIMEOUT).unwrap()
}

/// Read and parse every line of a sink file (skipping blanks).
fn parsed_records(sink: &TraceSink) -> Vec<akda::obs::trace::ParsedTrace> {
    let text = std::fs::read_to_string(sink.path()).unwrap();
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| parse_line(l).unwrap())
        .collect()
}

/// Acceptance: trace ids minted on the client arrive in the sink's
/// `akda-trace/1` records bit-for-bit, and every scored record carries
/// all five hop stages.
#[test]
fn trace_ids_cross_the_wire_bit_for_bit_into_the_sink() {
    let (root, registry, x) = one_tenant_registry("ids", 81);
    let svc = FleetService::start(&registry, FleetOptions::default()).unwrap();
    let sink = Arc::new(TraceSink::create(root.join("trace.jsonl"), 1, None).unwrap());
    let opts = NetOptions { trace: Some(sink.clone()), ..Default::default() };
    let server = NetServer::start("127.0.0.1:0", svc.client(), opts).unwrap();
    let mut c = connect(&server);

    let mut ids = TraceIdGen::new(0xC0FF_EE01);
    let mut minted = BTreeSet::new();
    for i in 0..6 {
        let id = ids.next_id();
        minted.insert(id);
        match c.score_traced("ta", x.row(i), id).unwrap().reply {
            NetReply::Scores(s) => assert_eq!(s.len(), 3),
            other => panic!("traced request must score, got {other:?}"),
        }
    }
    // joining the server's threads flushes every pending sink offer
    drop(c);
    drop(server);

    assert_eq!(sink.written(), 6, "sample=1 must capture every request");
    let records = parsed_records(&sink);
    let mut seen = BTreeSet::new();
    for rec in &records {
        assert!(!rec.shed);
        assert_eq!(rec.model, "ta");
        for (_, name) in STAGES {
            assert!(
                rec.stages.iter().any(|(s, _)| s == name),
                "record is missing stage {name}: {rec:?}"
            );
        }
        seen.insert(rec.trace);
    }
    assert_eq!(seen, minted, "trace ids must survive the wire bit-for-bit");

    drop(svc);
    let _ = std::fs::remove_dir_all(&root);
}

/// Acceptance: the server-timing echo lists the five stages in hop
/// order and their sum is bounded by the client-observed RTT; an
/// untraced request gets no echo.
#[test]
fn echoed_stage_sum_is_bounded_by_client_rtt() {
    let (root, registry, x) = one_tenant_registry("rtt", 82);
    let svc = FleetService::start(&registry, FleetOptions::default()).unwrap();
    let server = NetServer::start("127.0.0.1:0", svc.client(), NetOptions::default()).unwrap();
    let mut c = connect(&server);

    let hop_order: Vec<u8> = STAGES.iter().map(|&(id, _)| id).collect();
    let mut ids = TraceIdGen::new(7);
    for i in 0..8 {
        let traced = c.score_traced("ta", x.row(i % x.rows()), ids.next_id()).unwrap();
        match &traced.reply {
            NetReply::Scores(s) => assert_eq!(s.len(), 3),
            other => panic!("traced request must score, got {other:?}"),
        }
        let order: Vec<u8> = traced.timings.iter().map(|&(id, _)| id).collect();
        assert_eq!(order, hop_order, "echo must list the five stages in hop order");
        let sum_s: f64 = traced.timings.iter().map(|&(_, ns)| ns as f64 * 1e-9).sum();
        let rtt_s = traced.rtt.as_secs_f64();
        assert!(
            sum_s <= rtt_s,
            "stage sum {sum_s} s must be <= client rtt {rtt_s} s"
        );
    }

    // trace id 0 is the wire's "untraced" sentinel: no echo comes back
    let bare = c.score_traced("ta", x.row(0), 0).unwrap();
    assert!(matches!(bare.reply, NetReply::Scores(_)));
    assert!(bare.timings.is_empty(), "untraced requests must not be echoed");

    drop(c);
    drop(server);
    drop(svc);
    let _ = std::fs::remove_dir_all(&root);
}

/// Acceptance: `--trace-sample 3` writes exactly every 3rd record;
/// `--trace-slow-ms 0` (sampling off) captures every request.
#[test]
fn sink_policies_hold_over_the_wire() {
    let (root, registry, x) = one_tenant_registry("policy", 83);
    let svc = FleetService::start(&registry, FleetOptions::default()).unwrap();

    // sample every 3rd: 9 sequential requests -> records at seq 0, 3, 6
    let s3 = Arc::new(TraceSink::create(root.join("s3.jsonl"), 3, None).unwrap());
    {
        let opts = NetOptions { trace: Some(s3.clone()), ..Default::default() };
        let server = NetServer::start("127.0.0.1:0", svc.client(), opts).unwrap();
        let mut c = connect(&server);
        let mut ids = TraceIdGen::new(9);
        for i in 0..9 {
            let traced = c.score_traced("ta", x.row(i % x.rows()), ids.next_id()).unwrap();
            assert!(matches!(traced.reply, NetReply::Scores(_)));
        }
        drop(c);
    }
    assert_eq!(s3.written(), 3, "sample=3 must write exactly every 3rd record");

    // slow-ms 0 with sampling off: every request is "slow enough"
    let slow0 = Arc::new(TraceSink::create(root.join("slow0.jsonl"), 0, Some(0.0)).unwrap());
    {
        let opts = NetOptions { trace: Some(slow0.clone()), ..Default::default() };
        let server = NetServer::start("127.0.0.1:0", svc.client(), opts).unwrap();
        let mut c = connect(&server);
        let mut ids = TraceIdGen::new(10);
        for i in 0..5 {
            let traced = c.score_traced("ta", x.row(i % x.rows()), ids.next_id()).unwrap();
            assert!(matches!(traced.reply, NetReply::Scores(_)));
        }
        drop(c);
    }
    assert_eq!(slow0.written(), 5, "slow-ms 0 must capture every request");

    drop(svc);
    let _ = std::fs::remove_dir_all(&root);
}

/// Acceptance: a shed request leaves a terminal `net/queue` record with
/// `shed=true` and exactly the two ingress stages — one record per shed
/// the client observed, and one record per request overall.
#[test]
fn sheds_leave_terminal_net_queue_records() {
    let (root, registry, x) = one_tenant_registry("shed", 84);
    let svc = FleetService::start(&registry, FleetOptions::default()).unwrap();
    let sink = Arc::new(TraceSink::create(root.join("shed.jsonl"), 1, None).unwrap());
    let opts = NetOptions {
        queue_cap: 2,
        max_inflight: 1,
        retry_after_ms: 7,
        trace: Some(sink.clone()),
        ..Default::default()
    };
    let server = NetServer::start("127.0.0.1:0", svc.client(), opts).unwrap();
    let mut c = connect(&server);

    // burst 50 traced requests down one pipelined connection: the tiny
    // ingress (queue_cap 2, one in flight) must shed some of them
    const BURST: usize = 50;
    let mut ids = TraceIdGen::new(0x5EED_5EED);
    for i in 0..BURST {
        c.send_score_traced("ta", x.row(i % x.rows()), ids.next_id()).unwrap();
    }
    let (mut scored, mut shed) = (0usize, 0usize);
    for _ in 0..BURST {
        match c.recv().unwrap() {
            Frame::ScoreResponse { .. } => scored += 1,
            Frame::Error { code: ErrorCode::OverCapacity, retry_after_ms, .. } => {
                assert_eq!(retry_after_ms, 7);
                shed += 1;
            }
            other => panic!("expected scores or OverCapacity, got {other:?}"),
        }
    }
    assert_eq!(scored + shed, BURST);
    assert!(shed > 0, "a queue_cap=2 ingress must shed under a 50-deep burst");

    drop(c);
    drop(server);

    assert_eq!(sink.written(), BURST as u64, "sample=1 must record every request");
    let records = parsed_records(&sink);
    let shed_recs: Vec<_> = records.iter().filter(|r| r.shed).collect();
    assert_eq!(shed_recs.len(), shed, "one shed=true record per client-observed shed");
    for rec in &shed_recs {
        // the JSONL stages object is name-keyed, so parsed order is
        // alphabetical — compare the sorted set
        let mut names: Vec<&str> = rec.stages.iter().map(|(s, _)| s.as_str()).collect();
        names.sort_unstable();
        assert_eq!(
            names,
            vec!["net/queue", "net/read"],
            "a shed is terminal at net/queue: {rec:?}"
        );
        assert_ne!(rec.trace, 0, "the shed record must keep the client's trace id");
    }
    for rec in records.iter().filter(|r| !r.shed) {
        assert_eq!(rec.stages.len(), STAGES.len(), "scored records carry all stages");
    }

    drop(svc);
    let _ = std::fs::remove_dir_all(&root);
}

/// Acceptance: the exact byte sequence a pre-extension client sends (a
/// ScoreRequest with no trailing trace id — pinned byte-identical to
/// `encode(.. trace: 0)` by the wire codec's own tests) still decodes
/// and scores bit-for-bit against the in-process fleet client.
#[test]
fn pre_extension_request_bytes_still_score_bit_for_bit() {
    let (root, registry, x) = one_tenant_registry("compat", 85);
    let svc = FleetService::start(&registry, FleetOptions::default()).unwrap();
    let fleet = svc.client();
    let server = NetServer::start("127.0.0.1:0", svc.client(), NetOptions::default()).unwrap();
    let mut c = connect(&server);

    for i in 0..4 {
        let row = x.row(i);
        let bytes = encode(&Frame::ScoreRequest {
            req_id: 70 + i as u64,
            model: "ta".to_string(),
            features: row.to_vec(),
            trace: 0,
        });
        c.send_raw(&bytes).unwrap();
        match c.recv().unwrap() {
            Frame::ScoreResponse { req_id, scores, timings } => {
                assert_eq!(req_id, 70 + i as u64);
                assert!(timings.is_empty(), "old-format requests must get no echo");
                let want = fleet.score("ta", row.to_vec()).unwrap();
                let got_bits: Vec<u64> = scores.iter().map(|v| v.to_bits()).collect();
                let want_bits: Vec<u64> = want.iter().map(|v| v.to_bits()).collect();
                assert_eq!(got_bits, want_bits, "scores must match bit-for-bit");
            }
            other => panic!("pre-extension request must score, got {other:?}"),
        }
    }

    drop(c);
    drop(server);
    drop(svc);
    let _ = std::fs::remove_dir_all(&root);
}
