//! Integration: the out-of-core streaming path through the public API —
//! coordinator protocol with `Hyper::stream_block` set, block-size
//! invariance of the tiled solve, and CSV-to-projection training without
//! ever materializing the dataset or the N×m feature matrix.

use akda::coordinator::{evaluate_ovr, Hyper, MethodId};
use akda::da::akda_approx::AkdaApprox;
use akda::data::stream::{CsvBlockSource, MemBlockSource};
use akda::data::{by_name, Condition, Split};
use akda::kernels::Kernel;

fn tiny_split() -> Split {
    let mut d = by_name("eth80").unwrap();
    d.n_classes = 4;
    d.test_per_class = 20;
    d.split(Condition::Ex10)
}

#[test]
fn streamed_protocol_matches_in_memory_protocol() {
    let split = tiny_split();
    let hp = Hyper { rho: 0.05, c: 1.0, h: 1, m: 24, ..Default::default() };
    for id in [MethodId::AkdaNystrom, MethodId::AkdaRff] {
        let dense = evaluate_ovr(&split, id, hp, 1e-3, None, None).unwrap();
        // tiled runs at several block sizes, including B = 1 and B >= N
        let mut maps = Vec::new();
        for block in [1usize, 7, 4096] {
            let hp_s = Hyper { stream_block: Some(block), ..hp };
            let res = evaluate_ovr(&split, id, hp_s, 1e-3, None, None).unwrap();
            let peak = res.peak_f64.expect("streaming reports residency");
            assert!(peak > 0, "{}: peak residency", id.name());
            assert!(
                (res.map - dense.map).abs() < 0.02,
                "{} block={}: stream MAP {} vs dense {}",
                id.name(),
                block,
                res.map,
                dense.map
            );
            maps.push(res.map);
        }
        // the tiled accumulation is block-size invariant, so the whole
        // protocol (solve -> LSVM -> ranking) must agree exactly
        for m in &maps[1..] {
            assert_eq!(*m, maps[0], "{}: MAP must not depend on B", id.name());
        }
    }
}

#[test]
fn csv_file_trains_a_projection_out_of_core() {
    let split = tiny_split();
    let dir = std::env::temp_dir().join("akda_streaming_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("train.csv");
    akda::data::csv::save_labeled(&path, &split.x_train, &split.y_train).unwrap();

    let cfg = AkdaApprox::rff(Kernel::Rbf { rho: 0.05 }, 64);
    // out-of-core: 8-row tiles from disk
    let mut csv = CsvBlockSource::open(&path, 8).unwrap();
    let prep_csv = cfg.prepare_stream(&mut csv).unwrap();
    // same pipeline from memory — must agree bit-for-bit (the CSV writer
    // emits shortest-round-trip floats)
    let mut mem = MemBlockSource::new(&split.x_train, &split.y_train, 8);
    let prep_mem = cfg.prepare_stream(&mut mem).unwrap();

    assert_eq!(prep_csv.stats.rows, split.x_train.rows());
    assert_eq!(prep_csv.n_classes(), split.n_classes);
    let z_csv = prep_csv.fit_multiclass().unwrap();
    let z_mem = prep_mem.fit_multiclass().unwrap();
    assert!(z_csv.w.sub(&z_mem.w).max_abs() == 0.0);

    use akda::da::Projection;
    let z = z_csv.project(&split.x_test);
    assert_eq!(z.rows(), split.x_test.rows());
    assert_eq!(z.cols(), split.n_classes - 1);
    assert!(z.is_finite());
}
