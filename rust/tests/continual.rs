//! Integration: the online continual-learning loop (Sec. 7 recursive
//! learning wired through the model registry).
//!
//! Pins the PR's two acceptance guarantees:
//!
//! 1. **Update equivalence** — growing a published model with
//!    `model::update::apply_update` (bordered-Cholesky extension, zero
//!    full refits) over any append granularity {1, 7, all-at-once}
//!    matches a from-scratch AKDA fit on the concatenated data to
//!    ≤ 1e-10 in projected scores.
//! 2. **Live republish** — an updated version published to the registry
//!    is hot-swapped into a running scoring service without dropping
//!    requests, and the service then serves exactly the new version's
//!    scores (and reports its version).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use akda::coordinator::{BankHandle, DetectorBank, ScoringService};
use akda::da::akda::Akda;
use akda::da::incremental::IncrementalAkda;
use akda::da::DrMethod;
use akda::data::synthetic::{gaussian_classes, GaussianSpec};
use akda::kernels::Kernel;
use akda::linalg::Mat;
use akda::model::codec::{encode_resume, ExactResume};
use akda::model::update::train_svm_bank;
use akda::model::{
    apply_update, encode_bank, HotReloader, ModelManifest, ModelRegistry, ResumeState,
    UpdateOptions,
};

fn toy(n_per: usize, c: usize, seed: u64) -> (Mat, Vec<usize>) {
    gaussian_classes(&GaussianSpec {
        n_classes: c,
        n_per_class: vec![n_per; c],
        dim: 6,
        class_sep: 2.5,
        noise: 0.6,
        modes_per_class: 1,
        seed,
    })
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("akda_continual_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Exact-AKDA bank + artifact with embedded resume state — the same shape
/// `akda train --method akda` publishes.
fn exact_artifact(
    x: &Mat,
    labels: &[usize],
    n_classes: usize,
) -> (DetectorBank, akda::model::ModelArtifact) {
    let akda_cfg = Akda::new(Kernel::Rbf { rho: 0.4 });
    let (proj, chol_l) = akda_cfg.fit_with_factor(x, labels, n_classes).unwrap();
    let z = proj.project(x);
    let svms = train_svm_bank(&z, labels, n_classes);
    let bank = DetectorBank { projection: Box::new(proj), svms };
    let mut art = encode_bank(&bank, "akda").unwrap();
    encode_resume(
        &mut art,
        &ResumeState::Exact(ExactResume {
            chol_l,
            labels: labels.to_vec(),
            eps: akda_cfg.eps,
            n_classes,
        }),
    )
    .unwrap();
    (bank, art)
}

/// Acceptance: incremental `extend` over rows {1, 7, all-at-once} matches
/// a from-scratch AKDA fit on the concatenated data to ≤ 1e-10 in
/// projected scores.
#[test]
fn extend_matches_from_scratch_fit_at_every_granularity() {
    let (x, labels) = toy(15, 3, 1); // 45 rows total
    let n0 = 30; // base model: 30 rows, the remaining 15 arrive later
    let f = x.cols();
    let base_x = x.submatrix(0, 0, n0, f);
    let tail_x = x.submatrix(n0, 0, x.rows() - n0, f);
    let tail_y = &labels[n0..];
    let (xt, _) = toy(8, 3, 9);

    // from-scratch comparator on the full concatenated data
    let scratch = Akda::new(Kernel::Rbf { rho: 0.4 }).fit(&x, &labels, 3).unwrap();
    let z_scratch = scratch.project(&xt);

    for chunk in [1usize, 7, tail_x.rows()] {
        let akda_cfg = Akda::new(Kernel::Rbf { rho: 0.4 });
        let (_, chol_l) = akda_cfg.fit_with_factor(&base_x, &labels[..n0], 3).unwrap();
        let mut inc = IncrementalAkda::from_parts(
            akda_cfg.kernel,
            akda_cfg.eps,
            3,
            base_x.clone(),
            labels[..n0].to_vec(),
            chol_l,
        )
        .unwrap();
        let mut r0 = 0;
        while r0 < tail_x.rows() {
            let nr = chunk.min(tail_x.rows() - r0);
            inc.extend(&tail_x.submatrix(r0, 0, nr, f), &tail_y[r0..r0 + nr]).unwrap();
            r0 += nr;
        }
        assert_eq!(inc.len(), 45);
        assert_eq!(inc.growths(), 15, "every appended row is one bordered growth");
        let z_inc = inc.project(&xt).unwrap();
        let gap = z_inc.sub(&z_scratch).max_abs();
        assert!(
            gap <= 1e-10,
            "chunk={chunk}: projected scores differ from a from-scratch fit by {gap}"
        );
    }
}

/// Acceptance: `apply_update` on a published artifact — the CLI engine —
/// performs bordered growth only and matches the from-scratch fit.
#[test]
fn apply_update_matches_from_scratch_and_keeps_the_chain_updatable() {
    let (x, labels) = toy(12, 3, 2); // 36 rows
    let f = x.cols();
    let (_, art) = exact_artifact(&x.submatrix(0, 0, 24, f), &labels[..24], 3);

    // first update: 6 rows
    let (bank1, art1, rep1) = apply_update(
        &art,
        &x.submatrix(24, 0, 6, f),
        &labels[24..30],
        &UpdateOptions::default(),
    )
    .unwrap();
    assert_eq!((rep1.kind, rep1.appended, rep1.bordered_growths), ("exact-bordered", 6, 6));
    assert_eq!(rep1.full_refactorizations, 0);
    // second update continues from the republished artifact: 6 more rows
    let (bank2, art2, rep2) = apply_update(
        &art1,
        &x.submatrix(30, 0, 6, f),
        &labels[30..],
        &UpdateOptions::default(),
    )
    .unwrap();
    assert_eq!(rep2.total_rows, 36);
    assert!(matches!(
        akda::model::codec::decode_resume(&art2).unwrap(),
        Some(ResumeState::Exact(_))
    ));

    let scratch = Akda::new(Kernel::Rbf { rho: 0.4 }).fit(&x, &labels, 3).unwrap();
    let (xt, _) = toy(10, 3, 11);
    let gap1 = bank1
        .projection
        .project(&xt)
        .sub(&Akda::new(Kernel::Rbf { rho: 0.4 })
            .fit(&x.submatrix(0, 0, 30, f), &labels[..30], 3)
            .unwrap()
            .project(&xt))
        .max_abs();
    let gap2 = bank2.projection.project(&xt).sub(&scratch.project(&xt)).max_abs();
    assert!(gap1 <= 1e-10, "after update 1: gap {gap1}");
    assert!(gap2 <= 1e-10, "after chained update 2: gap {gap2}");
}

/// Acceptance: registry update → hot-swap under live traffic serves the
/// new version's scores without dropping a request.
#[test]
fn registry_update_hot_swaps_into_a_live_service() {
    let (x, labels) = toy(12, 3, 3); // 36 rows
    let f = x.cols();
    let n0 = 27;
    let root = tmpdir("update_swap");
    let registry = ModelRegistry::open(&root);

    // v1: train on the first 27 rows, publish with resume state
    let (_, art) = exact_artifact(&x.submatrix(0, 0, n0, f), &labels[..n0], 3);
    let manifest = ModelManifest {
        method: "akda".into(),
        n_classes: 3,
        input_dim: f,
        ..Default::default()
    };
    let e1 = registry.publish("cl", &art, &manifest).unwrap();
    assert_eq!(e1.version, 1);

    // serve v1 with a watcher, exactly like `akda serve --model cl --watch`
    let (entry, loaded) = registry.load_bank("cl").unwrap();
    let handle = BankHandle::new_versioned(Arc::new(loaded), entry.version);
    assert_eq!(handle.served_version(), 1);
    let svc = ScoringService::start_reloadable(
        handle.clone(),
        f,
        16,
        Duration::from_millis(2),
    );
    let client = svc.client();
    let probe = x.row(0).to_vec();
    let before = client.score(probe.clone()).unwrap();
    let watcher = HotReloader::start(
        registry.clone(),
        "cl".into(),
        handle.clone(),
        entry.version,
        f,
        Duration::from_millis(10),
        None,
    );

    // `akda update cl --data ...`: grow with the held-out 9 rows, publish v2
    let (_, artifact) = registry.load_artifact("cl").unwrap();
    let (updated_bank, new_art, report) = apply_update(
        &artifact,
        &x.submatrix(n0, 0, x.rows() - n0, f),
        &labels[n0..],
        &UpdateOptions::default(),
    )
    .unwrap();
    assert_eq!(report.full_refactorizations, 0);
    let mf2 = ModelManifest { updated_from: Some(e1.spec()), ..manifest.clone() };
    let e2 = registry.publish("cl", &new_art, &mf2).unwrap();
    assert_eq!(e2.version, 2);

    // the watcher swaps v2 in (bounded wait), without dropping traffic
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    while watcher.reloads() == 0 && std::time::Instant::now() < deadline {
        let answered = client.score(probe.clone()).unwrap();
        assert_eq!(answered.len(), 3, "requests must be answered across the swap");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(watcher.reloads() >= 1, "updated version never hot-swapped");
    assert_eq!(handle.served_version(), 2, "handle must report the served version");

    // the service now answers with exactly the updated bank's scores
    let after = client.score(probe.clone()).unwrap();
    let direct = updated_bank.score(&x.submatrix(0, 0, 1, f));
    assert_eq!(after, direct.row(0).to_vec(), "served scores must be v2's");
    assert_ne!(before, after, "the update must actually change the model");

    // provenance is recorded and the diff reports the section drift
    assert_eq!(
        registry.latest("cl").unwrap().manifest.updated_from,
        Some("cl@1".to_string())
    );
    let diff = registry.diff("cl@1", "cl@2").unwrap();
    assert!(
        diff.sections.iter().any(|s| s.contains("kernel.x_train")),
        "grown training set must show up in the diff: {:?}",
        diff.sections
    );

    // GC: prune keeps the served version even when asked to keep only 1
    let pruned = registry
        .prune("cl", 1, Some(handle.served_version()))
        .unwrap();
    assert_eq!(pruned, vec![1]);
    assert_eq!(registry.versions("cl").unwrap(), vec![2]);

    watcher.stop();
    let _ = std::fs::remove_dir_all(&root);
}

/// The exact update engine refuses artifacts without resume state, and
/// the error explains how to get one.
#[test]
fn update_requires_resume_state() {
    let (x, labels) = toy(8, 2, 5);
    let proj = Akda::new(Kernel::Rbf { rho: 0.4 }).fit(&x, &labels, 2).unwrap();
    let z = proj.project(&x);
    let svms = train_svm_bank(&z, &labels, 2);
    let bank = DetectorBank { projection: proj, svms };
    let art = encode_bank(&bank, "akda").unwrap();
    let err = apply_update(&art, &x, &labels, &UpdateOptions::default())
        .expect_err("must refuse");
    let msg = format!("{err:#}");
    assert!(msg.contains("resume") && msg.contains("akda train"), "{msg}");
}
