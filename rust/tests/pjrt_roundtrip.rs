//! Integration: the accelerated (PJRT artifact) path must agree with the
//! native Rust path — gram matrices, fit solutions, and end-to-end
//! projections, across buckets and kernels, including the exact-padding
//! contract.

use std::path::PathBuf;
use std::sync::Arc;

use akda::da::{akda::Akda, core, DrMethod};
use akda::data::synthetic::{gaussian_classes, GaussianSpec};
use akda::kernels::{self, Kernel};
use akda::linalg::{chol, Mat};
use akda::runtime::{AkdaPjrt, PjrtEngine};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The accelerated engine, or `None` (→ test skips) when it cannot run
/// here: either `artifacts/` is absent (`make artifacts` needs the
/// Python/JAX toolchain) or the XLA runtime is the offline stand-in
/// (`runtime::xla`), which loads manifests but refuses execution. Every
/// test below starts with `let Some(eng) = engine() else { return };`
/// so the suite documents itself as skipped instead of failing red on
/// machines without the accelerator stack.
fn engine() -> Option<Arc<PjrtEngine>> {
    let dir = artifacts_dir();
    let eng = match PjrtEngine::from_dir(&dir) {
        Ok(e) => Arc::new(e),
        Err(e) => {
            eprintln!(
                "SKIP pjrt_roundtrip: no artifacts at {dir:?} ({e}); \
                 run `make artifacts` with the JAX toolchain to enable"
            );
            return None;
        }
    };
    // probe one tiny execution: artifacts may exist while the PJRT
    // runtime itself is unavailable (offline xla stand-in)
    let (x, _) = problem(8, 2, 4, 0);
    match eng.gram(&x, Kernel::Rbf { rho: 0.5 }) {
        Ok(_) => Some(eng),
        Err(e) => {
            eprintln!("SKIP pjrt_roundtrip: PJRT runtime unavailable ({e})");
            None
        }
    }
}

fn problem(n_per: usize, c: usize, dim: usize, seed: u64) -> (Mat, Vec<usize>) {
    gaussian_classes(&GaussianSpec {
        n_classes: c,
        n_per_class: vec![n_per; c],
        dim,
        class_sep: 2.0,
        noise: 0.6,
        modes_per_class: 1,
        seed,
    })
}

#[test]
fn gram_artifact_matches_native() {
    let Some(eng) = engine() else { return };
    for &(n_per, dim, kernel) in &[
        (50, 10, Kernel::Rbf { rho: 0.25 }),
        (100, 64, Kernel::Rbf { rho: 0.05 }),
        (80, 30, Kernel::Linear),
    ] {
        let (x, _) = problem(n_per, 2, dim, 1);
        let got = eng.gram(&x, kernel).unwrap();
        let want = kernels::gram(&x, kernel);
        let err = got.sub(&want).max_abs();
        assert!(err < 5e-4, "kernel={kernel:?} err={err}");
    }
}

#[test]
fn fit_artifact_matches_native_solve() {
    let Some(eng) = engine() else { return };
    let (x, labels) = problem(60, 2, 16, 2);
    let theta = core::theta_binary(&labels);
    let psi_pjrt = eng.fit(&x, &theta, Kernel::Rbf { rho: 0.2 }).unwrap();
    // native solve with the same eps the artifact bakes (1e-3)
    let mut k = kernels::gram(&x, Kernel::Rbf { rho: 0.2 });
    k.add_ridge(1e-3);
    let psi_native = chol::spd_solve(&k, &theta, 64).unwrap();
    let scale = psi_native.max_abs();
    let err = psi_pjrt.sub(&psi_native).max_abs() / scale;
    assert!(err < 5e-3, "relative err={err}");
}

#[test]
fn fit_bucket_invariance() {
    // same problem solved through two buckets (pad to 256 vs 512) agrees
    let Some(eng) = engine() else { return };
    let (x, labels) = problem(100, 2, 16, 3); // n=200 → 256 bucket
    let theta = core::theta_binary(&labels);
    let psi_small = eng.fit(&x, &theta, Kernel::Rbf { rho: 0.3 }).unwrap();

    // force the 512 bucket by padding with extra zero-weight... instead:
    // append rows to exceed 256 and check consistency of the overlap is
    // not meaningful; rather check projections agree between buckets by
    // solving a 300-row problem (512 bucket) vs native.
    let (x2, labels2) = problem(150, 2, 16, 4); // n=300 → 512 bucket
    let theta2 = core::theta_binary(&labels2);
    let psi_big = eng.fit(&x2, &theta2, Kernel::Rbf { rho: 0.3 }).unwrap();
    let mut k2 = kernels::gram(&x2, Kernel::Rbf { rho: 0.3 });
    k2.add_ridge(1e-3);
    let want2 = chol::spd_solve(&k2, &theta2, 64).unwrap();
    assert!(psi_big.sub(&want2).max_abs() / want2.max_abs() < 5e-3);
    assert_eq!(psi_small.shape(), (200, 1));
    assert_eq!(psi_big.shape(), (300, 1));
}

#[test]
fn project_artifact_matches_native_chunked() {
    let Some(eng) = engine() else { return };
    let (x, labels) = problem(60, 2, 16, 5);
    let theta = core::theta_binary(&labels);
    let kernel = Kernel::Rbf { rho: 0.15 };
    let psi = eng.fit(&x, &theta, kernel).unwrap();
    // big test set to force chunking through the fixed n_te bucket
    let (x_test, _) = problem(700, 2, 16, 6); // 1400 rows > 1024 chunk
    let z_pjrt = eng.project(&x, &x_test, &psi, kernel).unwrap();
    let kc = kernels::cross_gram(&x_test, &x, kernel);
    let z_native = kc.matmul(&psi);
    let err = z_pjrt.sub(&z_native).max_abs() / z_native.max_abs().max(1e-12);
    assert!(err < 5e-3, "relative err={err}");
    assert_eq!(z_pjrt.shape(), (1400, 1));
}

#[test]
fn akda_pjrt_end_to_end_matches_native_akda() {
    let Some(eng) = engine() else { return };
    let kernel = Kernel::Rbf { rho: 0.2 };
    let (x, labels) = problem(70, 3, 16, 7);
    let accel = AkdaPjrt { kernel, engine: eng.clone() };
    let native = Akda::new(kernel);
    let pa = accel.fit(&x, &labels, 3).unwrap();
    let pn = native.fit(&x, &labels, 3).unwrap();
    let (x_test, _) = problem(40, 3, 16, 8);
    let za = pa.project(&x_test);
    let zn = pn.project(&x_test);
    let err = za.sub(&zn).max_abs() / zn.max_abs().max(1e-12);
    assert!(err < 1e-2, "relative err={err}");
    assert_eq!(pa.dim(), 2);
}

#[test]
fn multiclass_theta_through_pjrt() {
    let Some(eng) = engine() else { return };
    let (x, labels) = problem(30, 5, 16, 9);
    let kernel = Kernel::Rbf { rho: 0.3 };
    let accel = AkdaPjrt { kernel, engine: eng };
    let proj = accel.fit(&x, &labels, 5).unwrap();
    assert_eq!(proj.dim(), 4);
    let z = proj.project(&x);
    assert!(z.is_finite());
}

#[test]
fn linear_kernel_through_pjrt() {
    let Some(eng) = engine() else { return };
    let (x, labels) = problem(50, 2, 16, 10);
    let theta = core::theta_binary(&labels);
    let psi = eng.fit(&x, &theta, Kernel::Linear).unwrap();
    let mut k = kernels::gram(&x, Kernel::Linear);
    k.add_ridge(1e-3);
    let want = chol::spd_solve(&k, &theta, 64).unwrap();
    // linear gram is low-rank: compare projections K ψ (well-conditioned
    // functional of ψ) rather than raw coefficients
    let za = k.matmul(&psi);
    let zn = k.matmul(&want);
    assert!(za.sub(&zn).max_abs() / zn.max_abs() < 2e-2);
}

#[test]
fn handle_is_shareable_across_threads() {
    let Some(eng) = engine() else { return };
    let (x, labels) = problem(40, 2, 8, 11);
    let theta = core::theta_binary(&labels);
    std::thread::scope(|s| {
        for t in 0..4 {
            let eng = eng.clone();
            let x = &x;
            let theta = &theta;
            s.spawn(move || {
                let psi = eng.fit(x, theta, Kernel::Rbf { rho: 0.1 + t as f64 * 0.1 }).unwrap();
                assert!(psi.is_finite());
            });
        }
    });
}

#[test]
fn failure_injection_unknown_artifact_and_oversize() {
    let Some(eng) = engine() else { return };
    // unknown artifact name through the raw handle
    let err = eng
        .handle()
        .execute("fit_rbf_n999999_l64", vec![])
        .expect_err("unknown artifact must error");
    assert!(format!("{err}").contains("unknown artifact"));
    // problem larger than every bucket
    let (x, labels) = problem(2000, 2, 16, 12); // n=4000 > 2048 max bucket
    let theta = core::theta_binary(&labels);
    let err = eng.fit(&x, &theta, Kernel::Rbf { rho: 0.1 }).expect_err("oversize");
    assert!(format!("{err}").contains("bucket"), "{err}");
}

#[test]
fn failure_injection_theta_too_wide() {
    let Some(eng) = engine() else { return };
    let (x, _) = problem(30, 2, 8, 13);
    let wide = Mat::zeros(60, 64); // > D_max = 32
    let err = eng.fit(&x, &wide, Kernel::Rbf { rho: 0.1 }).expect_err("too wide");
    assert!(format!("{err}").contains("D_max"), "{err}");
}

#[test]
fn flush_cache_recompiles_transparently() {
    let Some(eng) = engine() else { return };
    let (x, labels) = problem(40, 2, 8, 14);
    let theta = core::theta_binary(&labels);
    let a = eng.fit(&x, &theta, Kernel::Rbf { rho: 0.2 }).unwrap();
    eng.handle().flush_cache();
    let b = eng.fit(&x, &theta, Kernel::Rbf { rho: 0.2 }).unwrap();
    assert!(a.sub(&b).max_abs() == 0.0, "recompiled executable must agree bit-exactly");
}
