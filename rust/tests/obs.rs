//! Integration: the L7 observability layer.
//!
//! Pins the PR's acceptance guarantees:
//!
//! 1. **Quantile accuracy** — log-bucketed histogram estimates stay
//!    within the documented half-bucket error of exact percentiles on a
//!    known distribution.
//! 2. **Concurrency** — counters are exact and gauges monotone under a
//!    multi-thread hammer (the fleet dispatch path records through the
//!    same relaxed atomics).
//! 3. **Per-tenant attribution** — a mixed-tenant fleet load lands in
//!    the right `{tenant=...}` instruments: requests, latency samples,
//!    and protocol rejects are never cross-charged.
//! 4. **Surface agreement** — one registry snapshot renders to both
//!    Prometheus text and `akda-metrics/1` JSON, and the JSON document
//!    round-trips the parser and the schema validator.

use akda::coordinator::{DetectorBank, FleetOptions, FleetService};
use akda::da::akda::Akda;
use akda::da::{DrMethod, Projection};
use akda::data::synthetic::{gaussian_classes, GaussianSpec};
use akda::kernels::Kernel;
use akda::model::update::train_svm_bank;
use akda::model::{encode_bank, ModelManifest, ModelRegistry};
use akda::obs;
use akda::obs::validate::{require_nonzero, validate_metrics_line};

#[test]
fn histogram_quantiles_track_exact_percentiles() {
    let h = obs::Histogram::new();
    // linear ramp 1..=1000 ms — the exact q-quantile is ~q seconds
    for i in 1..=1000 {
        h.record(i as f64 * 1e-3);
    }
    for (q, exact) in [(0.5, 0.5), (0.9, 0.9), (0.99, 0.99)] {
        let est = h.quantile(q);
        let rel = (est - exact).abs() / exact;
        assert!(rel < 0.15, "q{q}: estimate {est} vs exact {exact} (rel err {rel:.3})");
    }
    assert_eq!(h.count(), 1000);
    assert!((h.sum() - 500.5).abs() / 500.5 < 1e-3, "sum {}", h.sum());

    // a point mass lands every estimate in the same bucket
    let point = obs::Histogram::new();
    for _ in 0..100 {
        point.record(0.020);
    }
    for q in [0.5, 0.9, 0.99] {
        let rel = (point.quantile(q) - 0.020).abs() / 0.020;
        assert!(rel < 0.15, "point mass q{q} off by {rel:.3}");
    }
}

#[test]
fn counters_and_gauges_are_exact_under_concurrent_hammer() {
    let c = obs::Counter::new();
    let g = obs::Gauge::new();
    let peak = obs::Gauge::new();
    const THREADS: u64 = 8;
    const PER_THREAD: u64 = 10_000;
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let (c, g, peak) = (&c, &g, &peak);
            s.spawn(move || {
                for i in 0..PER_THREAD {
                    c.inc();
                    g.add(1.0);
                    peak.set_max((t * PER_THREAD + i) as f64);
                }
            });
        }
    });
    assert_eq!(c.get(), THREADS * PER_THREAD);
    assert_eq!(g.get(), (THREADS * PER_THREAD) as f64);
    assert_eq!(peak.get(), (THREADS * PER_THREAD - 1) as f64, "set_max keeps the maximum");
}

/// Exact-AKDA bank artifact, publishable and servable (no resume state —
/// the fleet only needs the bank).
fn tenant_artifact(
    dim: usize,
    n_classes: usize,
    seed: u64,
) -> (akda::linalg::Mat, akda::model::ModelArtifact) {
    let (x, labels) = gaussian_classes(&GaussianSpec {
        n_classes,
        n_per_class: vec![12; n_classes],
        dim,
        class_sep: 2.5,
        noise: 0.6,
        modes_per_class: 1,
        seed,
    });
    let akda_cfg = Akda::new(Kernel::Rbf { rho: 0.4 });
    let proj = akda_cfg.fit(&x, &labels, n_classes).unwrap();
    let z = proj.project(&x);
    let svms = train_svm_bank(&z, &labels, n_classes);
    let bank = DetectorBank { projection: proj, svms };
    let art = encode_bank(&bank, "akda").unwrap();
    (x, art)
}

#[test]
fn fleet_load_attributes_metrics_to_the_right_tenant() {
    let root = std::env::temp_dir().join(format!("akda_obs_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    let registry = ModelRegistry::open(&root);
    // unique tenant names: the obs registry is process-global, so these
    // instruments must belong to this test alone
    let (xa, art_a) = tenant_artifact(6, 3, 31);
    let (xb, art_b) = tenant_artifact(5, 2, 32);
    let mf = |dim, n_classes| ModelManifest {
        method: "akda".into(),
        n_classes,
        input_dim: dim,
        ..Default::default()
    };
    registry.publish("obs-aa", &art_a, &mf(6, 3)).unwrap();
    registry.publish("obs-bb", &art_b, &mf(5, 2)).unwrap();

    let svc = FleetService::start(&registry, FleetOptions::default()).unwrap();
    let client = svc.client();
    // mixed concurrent load: 12 requests per tenant across 4 threads
    std::thread::scope(|s| {
        for w in 0..4 {
            let client = client.clone();
            let (xa, xb) = (&xa, &xb);
            s.spawn(move || {
                for i in 0..3 {
                    let row = xa.row((w * 3 + i) % xa.rows()).to_vec();
                    assert_eq!(client.score("obs-aa", row).unwrap().len(), 3);
                    let row = xb.row((w * 3 + i) % xb.rows()).to_vec();
                    assert_eq!(client.score("obs-bb", row).unwrap().len(), 2);
                }
            });
        }
    });
    // one wrong-width request against obs-bb only
    assert!(client.score("obs-bb", vec![0.0; 6]).is_err());

    let requests = |t| obs::counter_with("akda_fleet_requests_total", &[("tenant", t)]).get();
    let latency = |t| obs::histogram_with("akda_fleet_latency_seconds", &[("tenant", t)]);
    let rejects = |t| {
        obs::counter_with("akda_fleet_rejects_total", &[("kind", "wrong_dim"), ("tenant", t)])
            .get()
    };
    assert_eq!(requests("obs-aa"), 12);
    assert_eq!(requests("obs-bb"), 12, "the reject must not count as a request");
    assert_eq!(latency("obs-aa").count(), 12);
    assert_eq!(latency("obs-bb").count(), 12);
    assert!(latency("obs-aa").quantile(0.99) > 0.0);
    assert_eq!(rejects("obs-bb"), 1);
    assert_eq!(rejects("obs-aa"), 0, "the reject must charge the offending tenant only");
    let version = |t| obs::gauge_with("akda_fleet_served_version", &[("model", t)]).get();
    assert_eq!((version("obs-aa"), version("obs-bb")), (1.0, 1.0));
    // the stats() snapshot is assembled from the same atomics
    let stats = svc.stats();
    assert_eq!(stats.per_tenant["obs-aa"], 12);
    assert_eq!(stats.per_tenant["obs-bb"], 12);
    assert_eq!(stats.rejected, 1);

    drop(client); // all clients must go first: the dispatcher drains on close
    drop(svc);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn snapshot_round_trips_between_prometheus_and_json() {
    // a local registry keeps this test independent of the global one
    let reg = obs::MetricsRegistry::new();
    reg.counter("rt_requests_total", &[("tenant", "t1")]).add(7);
    reg.gauge("rt_queue_depth", &[]).set(3.0);
    let h = reg.histogram("rt_latency_seconds", &[("tenant", "t1")]);
    for _ in 0..50 {
        h.record(0.010);
    }

    let snap = reg.snapshot();
    let prom = snap.to_prometheus();
    assert!(prom.contains("# TYPE rt_requests_total counter"), "{prom}");
    assert!(prom.contains("rt_requests_total{tenant=\"t1\"} 7"), "{prom}");
    assert!(prom.contains("rt_queue_depth 3"), "{prom}");
    assert!(prom.contains("rt_latency_seconds{tenant=\"t1\",quantile=\"0.99\"}"), "{prom}");
    assert!(prom.contains("rt_latency_seconds_count{tenant=\"t1\"} 50"), "{prom}");

    let doc = akda::util::json::parse(&snap.to_json(1234).to_string()).unwrap();
    validate_metrics_line(&doc).unwrap();
    require_nonzero(&doc, &["rt_requests_total", "rt_queue_depth", "rt_latency_seconds"])
        .unwrap();
    // the same instrument ids appear on both surfaces with the same values
    let counters = doc.get("counters").unwrap();
    let c = counters.get("rt_requests_total{tenant=\"t1\"}").unwrap();
    assert_eq!(c.as_usize(), Some(7));
    let summary = doc.get("summaries").unwrap().get("rt_latency_seconds{tenant=\"t1\"}").unwrap();
    assert_eq!(summary.get("count").unwrap().as_usize(), Some(50));
    assert!((h.sum() - 0.5).abs() < 1e-6, "sum {}", h.sum());
}
