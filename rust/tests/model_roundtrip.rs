//! Integration: the trained-model artifact subsystem end to end.
//!
//! The core guarantee of `model/`: for every servable method, a detector
//! bank that is trained, published to a registry, and loaded back scores
//! the test set **bit-for-bit** identically to the freshly trained bank —
//! and the load path performs zero training work (decode only). Corrupt
//! artifacts (truncation, bit flips) must fail with checksum errors, not
//! panics or silently wrong models. The hot-reload path must swap a newly
//! published version into a live scoring service without dropping
//! requests.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use akda::coordinator::protocol::approx_config;
use akda::coordinator::{
    build_dr, BankHandle, DetectorBank, Hyper, MethodId, ScoringService,
};
use akda::da::akda_stream::BlockedProjection;
use akda::da::{DrMethod, Projection};
use akda::data::stream::MemBlockSource;
use akda::data::{by_name, Condition, Split};
use akda::model::{
    decode_bank, encode_bank, HotReloader, ModelArtifact, ModelManifest, ModelRegistry,
};
use akda::svm::{LinearSvm, LinearSvmConfig};

fn tiny_split() -> Split {
    let mut d = by_name("mscorid").unwrap();
    d.n_classes = 4;
    d.test_per_class = 15;
    d.split(Condition::Ex10)
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("akda_model_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Train the multiclass projection + OvR LSVM bank for one method — the
/// same shape `akda train` builds.
fn train_bank(split: &Split, id: MethodId, stream_block: Option<usize>) -> DetectorBank {
    let hp = Hyper { rho: 0.05, c: 1.0, h: 2, m: 16, stream_block };
    let projection: Box<dyn Projection> = match stream_block {
        Some(block_rows) => {
            let ap = approx_config(id, hp, 1e-3);
            let mut src = MemBlockSource::new(&split.x_train, &split.y_train, block_rows);
            let prep = ap.prepare_stream(&mut src).unwrap();
            let w = prep.solve_w_multiclass().unwrap();
            Box::new(BlockedProjection { map: prep.map.clone(), w, block_rows })
        }
        None => build_dr(id, hp, 1e-3, None)
            .unwrap()
            .expect("DR method")
            .fit(&split.x_train, &split.y_train, split.n_classes)
            .unwrap(),
    };
    let z = projection.project(&split.x_train);
    let svms = (0..split.n_classes)
        .map(|cls| {
            let y: Vec<f64> = split
                .y_train
                .iter()
                .map(|&l| if l == cls { 1.0 } else { -1.0 })
                .collect();
            (format!("class{cls}"), LinearSvm::train(&z, &y, LinearSvmConfig::default()))
        })
        .collect();
    DetectorBank { projection, svms }
}

/// Every servable training path: exact AKDA/AKSDA kernel expansions, the
/// two approximate in-memory maps, and the streamed blocked projection.
fn servable_banks(split: &Split) -> Vec<(&'static str, DetectorBank)> {
    vec![
        ("akda", train_bank(split, MethodId::Akda, None)),
        ("aksda", train_bank(split, MethodId::Aksda, None)),
        ("akda-nystrom", train_bank(split, MethodId::AkdaNystrom, None)),
        ("akda-rff", train_bank(split, MethodId::AkdaRff, None)),
        ("akda-nystrom-stream", train_bank(split, MethodId::AkdaNystrom, Some(8))),
        ("akda-rff-stream", train_bank(split, MethodId::AkdaRff, Some(8))),
    ]
}

#[test]
fn every_servable_method_roundtrips_bit_for_bit() {
    let split = tiny_split();
    for (method, bank) in servable_banks(&split) {
        // through bytes, exactly as the registry stores them
        let artifact = encode_bank(&bank, method).unwrap();
        let restored = ModelArtifact::from_bytes(&artifact.to_bytes()).unwrap();
        let loaded = decode_bank(&restored).unwrap();

        let fresh_scores = bank.score(&split.x_test);
        let loaded_scores = loaded.score(&split.x_test);
        assert_eq!(
            fresh_scores, loaded_scores,
            "{method}: loaded bank must score bit-for-bit identically"
        );
        assert_eq!(loaded.class_names(), bank.class_names(), "{method}");
        assert_eq!(loaded.projection.dim(), bank.projection.dim(), "{method}");
    }
}

#[test]
fn publish_then_load_through_the_registry_is_bitwise_stable() {
    let split = tiny_split();
    let root = tmpdir("publish_load");
    let registry = ModelRegistry::open(&root);
    let bank = train_bank(&split, MethodId::AkdaNystrom, None);
    let fresh_scores = bank.score(&split.x_test);

    let artifact = encode_bank(&bank, "akda-nystrom").unwrap();
    let manifest = ModelManifest {
        method: "akda-nystrom".into(),
        dataset: "mscorid".into(),
        condition: "10Ex".into(),
        n_classes: split.n_classes,
        input_dim: split.x_train.cols(),
        ..Default::default()
    };
    let entry = registry.publish("roundtrip", &artifact, &manifest).unwrap();
    assert_eq!(entry.version, 1);

    let (loaded_entry, loaded) = registry.load_bank("roundtrip").unwrap();
    assert_eq!(loaded_entry.version, 1);
    assert_eq!(loaded_entry.manifest.method, "akda-nystrom");
    assert_eq!(loaded.score(&split.x_test), fresh_scores);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn truncated_artifacts_fail_with_checksum_errors_not_panics() {
    let split = tiny_split();
    let bank = train_bank(&split, MethodId::Akda, None);
    let bytes = encode_bank(&bank, "akda").unwrap().to_bytes();
    // cut at a spread of offsets including mid-header and mid-tensor
    for frac in [0.0, 0.1, 0.35, 0.5, 0.75, 0.95] {
        let cut = (bytes.len() as f64 * frac) as usize;
        let err = ModelArtifact::from_bytes(&bytes[..cut])
            .expect_err("truncated artifact must not decode");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("checksum") || msg.contains("truncated"),
            "cut={cut}: {msg}"
        );
    }
    // missing the final byte (classic partial write)
    assert!(ModelArtifact::from_bytes(&bytes[..bytes.len() - 1]).is_err());
}

#[test]
fn bit_flipped_artifacts_fail_with_checksum_errors_not_garbage_models() {
    let split = tiny_split();
    let bank = train_bank(&split, MethodId::AkdaRff, None);
    let bytes = encode_bank(&bank, "akda-rff").unwrap().to_bytes();
    // flip one bit at a spread of positions across the file (header, meta,
    // tensor payloads, checksums) — every one must be caught
    let step = (bytes.len() / 97).max(1);
    for i in (0..bytes.len()).step_by(step) {
        let mut bad = bytes.clone();
        bad[i] ^= 0x40;
        assert!(
            ModelArtifact::from_bytes(&bad).is_err(),
            "bit flip at byte {i}/{} went undetected",
            bytes.len()
        );
    }
}

#[test]
fn corrupt_artifact_on_disk_is_rejected_by_the_registry() {
    let split = tiny_split();
    let root = tmpdir("corrupt");
    let registry = ModelRegistry::open(&root);
    let bank = train_bank(&split, MethodId::Akda, None);
    let artifact = encode_bank(&bank, "akda").unwrap();
    let entry = registry
        .publish("corrupt", &artifact, &ModelManifest::default())
        .unwrap();
    // flip a byte in the stored artifact
    let path = entry.artifact_path();
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xff;
    std::fs::write(&path, &bytes).unwrap();
    let err = registry.load_bank("corrupt").expect_err("corrupt model must not load");
    assert!(format!("{err:#}").contains("checksum"), "{err:#}");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn hot_reload_swaps_a_newly_published_version_under_live_traffic() {
    let split = tiny_split();
    let root = tmpdir("hot_reload");
    let registry = ModelRegistry::open(&root);

    let bank_v1 = train_bank(&split, MethodId::Akda, None);
    let v1_scores = bank_v1.score(&split.x_test);
    let manifest = ModelManifest {
        input_dim: split.x_train.cols(),
        n_classes: split.n_classes,
        ..Default::default()
    };
    let a1 = encode_bank(&bank_v1, "akda").unwrap();
    let e1 = registry.publish("live", &a1, &manifest).unwrap();

    let (entry, loaded) = registry.load_bank("live").unwrap();
    let handle = BankHandle::new(Arc::new(loaded));
    let svc = ScoringService::start_reloadable(
        handle.clone(),
        split.x_train.cols(),
        16,
        Duration::from_millis(2),
    );
    let client = svc.client();
    let before = client.score(split.x_test.row(0).to_vec()).unwrap();
    assert_eq!(before, v1_scores.row(0).to_vec());

    let watcher = HotReloader::start(
        registry.clone(),
        "live".into(),
        handle.clone(),
        entry.version,
        split.x_train.cols(),
        Duration::from_millis(10),
        None,
    );

    // publish v2 with a visibly different detector bank (zeroed SVMs)
    let mut bank_v2 = train_bank(&split, MethodId::Akda, None);
    for (_, svm) in bank_v2.svms.iter_mut() {
        svm.w.iter_mut().for_each(|w| *w = 0.0);
        svm.b = 0.0;
    }
    let a2 = encode_bank(&bank_v2, "akda").unwrap();
    let e2 = registry.publish("live", &a2, &manifest).unwrap();
    assert_eq!(e2.version, e1.version + 1);

    // wait for the watcher to pick it up (bounded)
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    while watcher.reloads() == 0 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(watcher.reloads() >= 1, "hot reload never happened");
    assert!(handle.generation() >= 1);

    // the service now answers with the v2 bank — all-zero scores — and
    // requests issued across the swap were all answered
    let after = client.score(split.x_test.row(0).to_vec()).unwrap();
    assert!(after.iter().all(|s| *s == 0.0), "v2 must serve: {after:?}");
    watcher.stop();
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn decode_is_pure_deserialization_zero_training_work() {
    // the load path must not depend on anything but the artifact bytes:
    // decoding twice gives banks that score identically, and decoding
    // works without any dataset/split in scope (no fit inputs exist here)
    let split = tiny_split();
    let bytes = {
        let bank = train_bank(&split, MethodId::AkdaNystrom, Some(4));
        encode_bank(&bank, "akda-nystrom").unwrap().to_bytes()
    };
    let a = decode_bank(&ModelArtifact::from_bytes(&bytes).unwrap()).unwrap();
    let b = decode_bank(&ModelArtifact::from_bytes(&bytes).unwrap()).unwrap();
    assert_eq!(a.score(&split.x_test), b.score(&split.x_test));
}
