# L2: the AKDA/AKSDA compute graphs (build-time JAX; never imported at
# runtime).
#
# Two graphs are lowered per shape bucket (python/compile/aot.py):
#
#   fit(x, theta, rho, mask)        -> psi        (AKDA Alg. 1 steps 3-4)
#   project(x_train, x_test, psi, rho, mask) -> z (Eq. 11: z = Psi^T k)
#
# The tiny O_b / O_bs eigenproblem (Alg. 1 step 1-2 / Alg. 2 step 1-2) runs
# natively in the Rust coordinator (C x C / H x H, cost O(C^3) per Sec. 4.5)
# and arrives here as `theta` (AKDA's Theta, Eq. 40, or AKSDA's V, Eq. 66 —
# the graphs are identical from that point on, which is exactly the paper's
# framing: both reduce to K Psi = Theta).
#
# Padding contract (DESIGN.md Sec. 5): rows of x beyond the mask are zero,
# gram forces the padded block to identity, padded theta rows are zero, so
# padded psi rows are exactly zero and unused trailing theta columns yield
# exactly-zero psi columns.
import functools

import jax
import jax.numpy as jnp

from compile.kernels import chol, gram


@functools.partial(jax.jit, static_argnames=("rbf", "tile", "block"))
def akda_fit(x, theta, rho, mask, *, rbf: bool,
             tile: int = gram.DEFAULT_TILE,
             block: int = chol.DEFAULT_BLOCK,
             eps: float = 1e-3):
    """Solve K Psi = Theta (Eq. 44 / Eq. 70).

    Args:
      x:     (N, L) f32, zero-padded observations (row-major observations).
      theta: (N, D) f32, eigenvector matrix of C_b (or V of C_bs); padded
             rows / unused columns are zero.
      rho:   (1, 1) f32 RBF bandwidth.
      mask:  (N, 1) f32 {0,1} validity.
    Returns:
      psi: (N, D) f32 expansion coefficients.
    """
    n = x.shape[0]
    k = gram.gram_matrix(x, mask, rho, rbf=rbf, tile=tile)
    # Ridge regularization for ill-conditioned K (Sec. 4.3). Padded diagonal
    # entries become 1 + eps — harmless, their theta rows are zero.
    k = k + eps * jnp.eye(n, dtype=jnp.float32)
    return chol.spd_solve(k, theta, block=block)


@functools.partial(jax.jit, static_argnames=("rbf", "tile"))
def akda_project(x_train, x_test, psi, rho, mask_train, *,
                 rbf: bool, tile: int = gram.DEFAULT_TILE):
    """Project test observations: Z = K_cross @ Psi (Eq. 11, batched)."""
    kc = gram.cross_kernel(x_test, x_train, mask_train, rho, rbf=rbf, tile=tile)
    return kc @ psi


@functools.partial(jax.jit, static_argnames=("rbf", "tile"))
def gram_only(x, rho, mask, *, rbf: bool, tile: int = gram.DEFAULT_TILE):
    """Standalone masked Gram artifact — used by the Rust native engines
    (KDA/SRKDA/... baselines can offload the 2N^2F gram hot spot to PJRT
    while doing their own dense algebra)."""
    return gram.gram_matrix(x, mask, rho, rbf=rbf, tile=tile)
