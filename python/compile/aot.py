# AOT emitter: lower the L2 graphs to HLO *text* artifacts + manifest.json.
#
# HLO text (NOT lowered.compiler_ir("hlo") protos / .serialize()): jax >= 0.5
# emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
# (the runtime the Rust `xla` crate links) rejects; the text parser reassigns
# ids and round-trips cleanly. See /opt/xla-example/README.md.
#
# One artifact per (graph, N, L, kernel) shape bucket; the Rust runtime picks
# the smallest bucket >= the live problem and zero-pads (exact, not
# approximate — see DESIGN.md Sec. 5).
#
# Usage: cd python && python -m compile.aot --out-dir ../artifacts [--quick]
import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Shape buckets (DESIGN.md Sec. 5). D_MAX bounds the discriminant subspace
# width: C-1 for AKDA, H-1 for AKSDA; unused columns are zero-padded.
FIT_N = [256, 512, 1024, 2048]
FEAT_L = [64, 256]
D_MAX = 32
TEST_N = 1024
KERNELS = ["linear", "rbf"]

QUICK_FIT_N = [256]
QUICK_FEAT_L = [64]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def lower_fit(n, l, kernel):
    fn = lambda x, theta, rho, mask: model.akda_fit(
        x, theta, rho, mask, rbf=(kernel == "rbf"))
    return jax.jit(fn).lower(_spec(n, l), _spec(n, D_MAX), _spec(1, 1), _spec(n, 1))


def lower_project(n_tr, n_te, l, kernel):
    fn = lambda xtr, xte, psi, rho, mask: model.akda_project(
        xtr, xte, psi, rho, mask, rbf=(kernel == "rbf"))
    return jax.jit(fn).lower(
        _spec(n_tr, l), _spec(n_te, l), _spec(n_tr, D_MAX), _spec(1, 1),
        _spec(n_tr, 1))


def lower_gram(n, l, kernel):
    fn = lambda x, rho, mask: model.gram_only(
        x, rho, mask, rbf=(kernel == "rbf"))
    return jax.jit(fn).lower(_spec(n, l), _spec(1, 1), _spec(n, 1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only the smallest bucket (CI smoke)")
    ap.add_argument("--max-n", type=int, default=0,
                    help="drop fit buckets larger than this (0 = keep all)")
    args = ap.parse_args()

    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    fit_ns = QUICK_FIT_N if args.quick else FIT_N
    feat_ls = QUICK_FEAT_L if args.quick else FEAT_L
    if args.max_n:
        fit_ns = [n for n in fit_ns if n <= args.max_n]

    manifest = {"d_max": D_MAX, "entries": []}

    def emit(name, lowered, inputs, outputs):
        text = to_hlo_text(lowered)
        path = out / f"{name}.hlo.txt"
        path.write_text(text)
        manifest["entries"].append({
            "name": name,
            "file": path.name,
            "inputs": inputs,
            "outputs": outputs,
        })
        print(f"  {name}: {len(text)} chars")

    for kernel in KERNELS:
        for l in feat_ls:
            for n in fit_ns:
                print(f"lowering fit n={n} l={l} kernel={kernel}")
                emit(
                    f"fit_{kernel}_n{n}_l{l}",
                    lower_fit(n, l, kernel),
                    inputs=[
                        {"name": "x", "shape": [n, l]},
                        {"name": "theta", "shape": [n, D_MAX]},
                        {"name": "rho", "shape": [1, 1]},
                        {"name": "mask", "shape": [n, 1]},
                    ],
                    outputs=[{"name": "psi", "shape": [n, D_MAX]}],
                )
                print(f"lowering gram n={n} l={l} kernel={kernel}")
                emit(
                    f"gram_{kernel}_n{n}_l{l}",
                    lower_gram(n, l, kernel),
                    inputs=[
                        {"name": "x", "shape": [n, l]},
                        {"name": "rho", "shape": [1, 1]},
                        {"name": "mask", "shape": [n, 1]},
                    ],
                    outputs=[{"name": "k", "shape": [n, n]}],
                )
                n_te = QUICK_FIT_N[0] if args.quick else TEST_N
                print(f"lowering project n_tr={n} n_te={n_te} l={l} kernel={kernel}")
                emit(
                    f"project_{kernel}_ntr{n}_nte{n_te}_l{l}",
                    lower_project(n, n_te, l, kernel),
                    inputs=[
                        {"name": "x_train", "shape": [n, l]},
                        {"name": "x_test", "shape": [n_te, l]},
                        {"name": "psi", "shape": [n, D_MAX]},
                        {"name": "rho", "shape": [1, 1]},
                        {"name": "mask_train", "shape": [n, 1]},
                    ],
                    outputs=[{"name": "z", "shape": [n_te, D_MAX]}],
                )

    (out / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {len(manifest['entries'])} artifacts to {out}")


if __name__ == "__main__":
    main()
