# L1 Pallas kernels: tiled Gram / cross-kernel matrices.
#
# These are the paper's first hot spot (cost 2*N^2*F, Sec. 4.5). Each kernel
# is a Pallas grid over (i, j) output tiles; operand tiles (TM, L) / (TN, L)
# stream into VMEM and the inner contraction targets the MXU. Padding is
# handled *exactly*: rows/cols beyond the mask are forced to the identity,
# so K_padded = blockdiag(K, I) stays SPD and its Cholesky factor is
# blockdiag(chol(K), I).
#
# interpret=True always: the CPU PJRT plugin cannot execute Mosaic
# custom-calls; interpret mode lowers to plain HLO (while loops + dots).
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 128


def _gram_tile_kernel(x_i_ref, x_j_ref, m_i_ref, m_j_ref, rho_ref, o_ref,
                      *, rbf: bool, tm: int, tn: int):
    """One (tm, tn) tile of the masked Gram matrix.

    K[i, j] = mask_i * mask_j * k(x_i, x_j) + (1 - mask_i * mask_j) * delta_ij
    """
    xi = x_i_ref[...]                      # (tm, L)
    xj = x_j_ref[...]                      # (tn, L)
    g = jnp.dot(xi, xj.T, preferred_element_type=jnp.float32)  # MXU contraction
    if rbf:
        rho = rho_ref[0, 0]
        ni = jnp.sum(xi * xi, axis=1, keepdims=True)           # (tm, 1)
        nj = jnp.sum(xj * xj, axis=1, keepdims=True)           # (tn, 1)
        d2 = jnp.maximum(ni + nj.T - 2.0 * g, 0.0)
        k = jnp.exp(-rho * d2)
    else:
        k = g
    mi = m_i_ref[...]                      # (tm, 1)
    mj = m_j_ref[...]                      # (tn, 1)
    m = mi * mj.T                          # (tm, tn)
    rows = pl.program_id(0) * tm + jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 0)
    cols = pl.program_id(1) * tn + jax.lax.broadcasted_iota(jnp.int32, (tm, tn), 1)
    eye = (rows == cols).astype(jnp.float32)
    o_ref[...] = m * k + (1.0 - m) * eye


def _cross_tile_kernel(xe_ref, xt_ref, m_t_ref, rho_ref, o_ref, *, rbf: bool):
    """One tile of the test-vs-train cross kernel K[e, t] = k(x_e, x_t).

    Padded *train* columns are masked to zero (they multiply zero rows of
    Psi anyway; masking keeps the artifact's output exactly equal to the
    unpadded computation). Padded test rows produce garbage rows that the
    caller slices away.
    """
    xe = xe_ref[...]
    xt = xt_ref[...]
    g = jnp.dot(xe, xt.T, preferred_element_type=jnp.float32)
    if rbf:
        rho = rho_ref[0, 0]
        ne = jnp.sum(xe * xe, axis=1, keepdims=True)
        nt = jnp.sum(xt * xt, axis=1, keepdims=True)
        d2 = jnp.maximum(ne + nt.T - 2.0 * g, 0.0)
        k = jnp.exp(-rho * d2)
    else:
        k = g
    o_ref[...] = k * m_t_ref[...].T


def _pick_tile(n: int, tile: int) -> int:
    """Largest divisor of n that is <= tile (shapes are bucket-padded, so n
    is a multiple of a power of two; this always lands on a sane tile)."""
    t = min(tile, n)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("rbf", "tile"))
def gram_matrix(x, mask, rho, *, rbf: bool, tile: int = DEFAULT_TILE):
    """Masked Gram matrix via the Pallas tile kernel.

    Args:
      x:    (N, L) f32 observations (rows), zero-padded beyond the mask.
      mask: (N, 1) f32 {0, 1} row validity.
      rho:  (1, 1) f32 RBF bandwidth (ignored for linear).
      rbf:  kernel type.
    Returns: (N, N) f32, K = blockdiag(K_valid, I_pad).
    """
    n, l = x.shape
    tm = _pick_tile(n, tile)
    grid = (n // tm, n // tm)
    kern = functools.partial(_gram_tile_kernel, rbf=rbf, tm=tm, tn=tm)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, l), lambda i, j: (i, 0)),
            pl.BlockSpec((tm, l), lambda i, j: (j, 0)),
            pl.BlockSpec((tm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((tm, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(x, x, mask, mask, rho)


@functools.partial(jax.jit, static_argnames=("rbf", "tile"))
def cross_kernel(x_test, x_train, mask_train, rho, *, rbf: bool,
                 tile: int = DEFAULT_TILE):
    """Cross kernel matrix k(x_test_e, x_train_t), train-masked.

    Shapes: x_test (Ne, L), x_train (Nt, L), mask_train (Nt, 1).
    Returns (Ne, Nt) f32.
    """
    ne, l = x_test.shape
    nt, _ = x_train.shape
    tme = _pick_tile(ne, tile)
    tmt = _pick_tile(nt, tile)
    grid = (ne // tme, nt // tmt)
    kern = functools.partial(_cross_tile_kernel, rbf=rbf)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tme, l), lambda i, j: (i, 0)),
            pl.BlockSpec((tmt, l), lambda i, j: (j, 0)),
            pl.BlockSpec((tmt, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tme, tmt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ne, nt), jnp.float32),
        interpret=True,
    )(x_test, x_train, mask_train, rho)
