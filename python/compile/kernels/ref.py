# Pure-jnp / numpy correctness oracles for the L1 kernels and the L2 model.
#
# Everything here is allowed to use jnp.linalg / scipy (these run only under
# pytest, never in an artifact), and is written as the most literal
# transcription of the paper's equations.
import numpy as np


def ref_gram_linear(x):
    """K = X X^T (Eq. 9 with the linear kernel; x rows are observations)."""
    return x @ x.T


def ref_gram_rbf(x, rho):
    """K[i,j] = exp(-rho * ||x_i - x_j||^2) (Sec. 6.3.1 base kernel)."""
    sq = np.sum(x * x, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    return np.exp(-rho * np.maximum(d2, 0.0))


def ref_cross_linear(x_test, x_train):
    return x_test @ x_train.T


def ref_cross_rbf(x_test, x_train, rho):
    se = np.sum(x_test * x_test, axis=1)
    st = np.sum(x_train * x_train, axis=1)
    d2 = se[:, None] + st[None, :] - 2.0 * (x_test @ x_train.T)
    return np.exp(-rho * np.maximum(d2, 0.0))


def ref_masked_gram(x, mask, rho, rbf):
    """The exact contract of kernels.gram.gram_matrix: valid block = kernel,
    padded block = identity."""
    k = ref_gram_rbf(x, rho) if rbf else ref_gram_linear(x)
    m = mask.reshape(-1)
    mm = np.outer(m, m)
    return mm * k + (1.0 - mm) * np.eye(x.shape[0])


def ref_chol(a):
    return np.linalg.cholesky(a)


def ref_spd_solve(a, b):
    return np.linalg.solve(a, b)


# ---------------------------------------------------------------------------
# Paper-level oracles (AKDA Algorithm 1 / AKSDA Algorithm 2).
# ---------------------------------------------------------------------------

def ref_core_matrix(counts):
    """O_b = I_C - n. n.^T / (n.^T n.)  (Eq. 30), n. = sqrt(class counts)."""
    nd = np.sqrt(np.asarray(counts, dtype=np.float64))
    return np.eye(len(counts)) - np.outer(nd, nd) / nd.dot(nd)


def ref_theta(labels, n_classes):
    """Theta = R_C N_C^{-1/2} Xi (Eq. 40) from the NZEP of O_b (Eq. 39)."""
    labels = np.asarray(labels)
    counts = np.array([(labels == i).sum() for i in range(n_classes)])
    ob = ref_core_matrix(counts)
    w, v = np.linalg.eigh(ob)
    xi = v[:, w > 0.5]                      # eigenvalues are exactly {0, 1}
    r = np.zeros((labels.size, n_classes))
    r[np.arange(labels.size), labels] = 1.0
    return (r / np.sqrt(counts)[None, :]) @ xi


def ref_theta_binary(n1, n2):
    """Analytic binary-class eigenvector theta (Eq. 50), sign-fixed to the
    '+' branch (first-class entries positive)."""
    n = n1 + n2
    t = np.concatenate([
        np.full(n1, np.sqrt(n2 / (n1 * n))),
        np.full(n2, -np.sqrt(n1 / (n2 * n))),
    ])
    return t[:, None]


def ref_akda_fit(x, labels, n_classes, rho, rbf=True, eps=1e-3):
    """AKDA Algorithm 1, literal: K Psi = Theta via dense solve."""
    k = ref_gram_rbf(x, rho) if rbf else ref_gram_linear(x)
    k = k + eps * np.eye(x.shape[0])
    theta = ref_theta(labels, n_classes)
    psi = np.linalg.solve(k, theta)
    return psi, theta, k


def ref_akda_project(x_train, x_test, psi, rho, rbf=True):
    kc = ref_cross_rbf(x_test, x_train, rho) if rbf else ref_cross_linear(x_test, x_train)
    return kc @ psi


def ref_scatter_kernel_matrices(x, labels, n_classes, rho, rbf=True):
    """S_b, S_w, S_t by the direct definitions (Eqs. 7, 8, 20) — used to
    verify the factorizations S_b = K C_b K etc. and the simultaneous
    reduction identities (45)-(47)."""
    n = x.shape[0]
    k = ref_gram_rbf(x, rho) if rbf else ref_gram_linear(x)
    one_n = np.ones(n) / n
    sb = np.zeros((n, n))
    sw = np.zeros((n, n))
    mu = k @ one_n
    for i in range(n_classes):
        idx = np.where(np.asarray(labels) == i)[0]
        ni = len(idx)
        eta_i = k[:, idx].mean(axis=1)
        d = eta_i - mu
        sb += ni * np.outer(d, d)
        for nn in idx:
            dv = k[:, nn] - eta_i
            sw += np.outer(dv, dv)
    st = np.zeros((n, n))
    for nn in range(n):
        dv = k[:, nn] - mu
        st += np.outer(dv, dv)
    return sb, sw, st


def ref_central_factors(labels, n_classes):
    """C_b, C_w, C_t (Eq. 29)."""
    labels = np.asarray(labels)
    n = labels.size
    counts = np.array([(labels == i).sum() for i in range(n_classes)], dtype=np.float64)
    r = np.zeros((n, n_classes))
    r[np.arange(n), labels] = 1.0
    ob = ref_core_matrix(counts)
    ninv_h = np.diag(1.0 / np.sqrt(counts))
    cb = r @ ninv_h @ ob @ ninv_h @ r.T
    cw = np.eye(n) - r @ np.diag(1.0 / counts) @ r.T
    ct = np.eye(n) - np.ones((n, n)) / n
    return cb, cw, ct


# --- AKSDA oracles ----------------------------------------------------------

def ref_core_matrix_subclass(class_of, counts):
    """O_bs element-wise (Sec. 5.1): diag N-N_i, 0 within class, else
    -sqrt(N_ij N_kl), all over N."""
    counts = np.asarray(counts, dtype=np.float64)
    class_of = np.asarray(class_of)
    h = len(counts)
    n = counts.sum()
    class_tot = np.array([counts[class_of == c].sum()
                          for c in range(class_of.max() + 1)])
    ob = np.zeros((h, h))
    for a in range(h):
        for b in range(h):
            if a == b:
                ob[a, b] = n - class_tot[class_of[a]]
            elif class_of[a] == class_of[b]:
                ob[a, b] = 0.0
            else:
                ob[a, b] = -np.sqrt(counts[a] * counts[b])
    return ob / n


def ref_v_matrix(sub_labels, class_of, n_sub):
    """V = R_H N_H^{-1/2} U (Eq. 66) from the NZEP of O_bs (Eq. 65)."""
    sub_labels = np.asarray(sub_labels)
    counts = np.array([(sub_labels == j).sum() for j in range(n_sub)])
    obs = ref_core_matrix_subclass(class_of, counts)
    w, u = np.linalg.eigh(obs)
    order = np.argsort(w)[::-1]
    w, u = w[order], u[:, order]
    keep = w > 1e-10
    u, w = u[:, keep], w[keep]
    r = np.zeros((sub_labels.size, n_sub))
    r[np.arange(sub_labels.size), sub_labels] = 1.0
    v = (r / np.sqrt(counts)[None, :]) @ u
    return v, w


def ref_aksda_fit(x, sub_labels, class_of, n_sub, rho, rbf=True, eps=1e-3):
    k = ref_gram_rbf(x, rho) if rbf else ref_gram_linear(x)
    k = k + eps * np.eye(x.shape[0])
    v, w = ref_v_matrix(sub_labels, class_of, n_sub)
    psi = np.linalg.solve(k, v)
    return psi, v, w
