# L1: blocked Cholesky factorization + blocked triangular solves in pure
# jax.numpy / lax control flow.
#
# This is the paper's second hot spot (N^3/3 flops, Sec. 4.5). We can NOT
# use jnp.linalg.cholesky / jax.scipy solve_triangular here: on CPU those
# lower to jaxlib LAPACK custom-calls (lapack_spotrf / lapack_strsm) that
# the standalone xla_extension PJRT runtime used by the Rust coordinator
# does not register. Everything below lowers to plain HLO (while loops,
# dynamic slices, dots), so the artifact runs on any PJRT backend.
#
# Structure mirrors the tiled GPU algorithm the paper cites [13,14],
# re-thought for TPU (DESIGN.md "Hardware adaptation"): the trailing SYRK
# update -- where ~all the flops live -- is a big matmul (MXU); only the
# small diagonal panel runs the scalar recurrence.
import functools

import jax
import jax.numpy as jnp
from jax import lax

DEFAULT_BLOCK = 128


def chol_unblocked(a, eps: float = 0.0):
    """Cholesky of a small SPD block via the outer-product recurrence.

    Column j of L is computed from the running trailing matrix, then the
    rank-one outer product is subtracted. fori_loop keeps the HLO compact.
    """
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(j, state):
        a_cur, l_acc = state
        d = jnp.sqrt(jnp.maximum(a_cur[j, j], eps) + eps)
        lcol = jnp.where(idx >= j, a_cur[:, j] / d, 0.0)
        l_acc = l_acc.at[:, j].set(lcol)
        a_cur = a_cur - jnp.outer(lcol, lcol)
        return a_cur, l_acc

    _, l_out = lax.fori_loop(0, n, body, (a, jnp.zeros_like(a)))
    return l_out


def solve_lower_unblocked(l, c):
    """Forward substitution: solve L @ Y = C for small lower-triangular L.

    L: (B, B), C: (B, M). Rows of Y fill top-down; row i only consumes
    already-filled rows (the still-zero rows contribute nothing).
    """
    b = l.shape[0]

    def body(i, y):
        yi = (c[i, :] - l[i, :] @ y) / l[i, i]
        return y.at[i, :].set(yi)

    return lax.fori_loop(0, b, body, jnp.zeros_like(c))


def solve_upper_unblocked(u, c):
    """Backward substitution: solve U @ Y = C for small upper-triangular U."""
    b = u.shape[0]

    def body(k, y):
        i = b - 1 - k
        yi = (c[i, :] - u[i, :] @ y) / u[i, i]
        return y.at[i, :].set(yi)

    return lax.fori_loop(0, b, body, jnp.zeros_like(c))


def _pick_block(n: int, block: int) -> int:
    t = min(block, n)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("block",))
def chol_blocked(a, *, block: int = DEFAULT_BLOCK, eps: float = 0.0):
    """Blocked right-looking Cholesky: A = L @ L.T, L lower triangular.

    The block loop is a static python loop (shapes per panel are static),
    so slicing is plain static slicing; only the small panel recurrences
    use dynamic control flow.
    """
    n = a.shape[0]
    b = _pick_block(n, block)
    nb = n // b
    l_out = jnp.zeros_like(a)
    for k in range(nb):
        s = k * b
        e = s + b
        l_kk = chol_unblocked(a[s:e, s:e], eps=eps)
        l_out = l_out.at[s:e, s:e].set(l_kk)
        if e < n:
            # Panel: solve L_panel @ L_kk.T = A[e:, s:e]
            #   <=>  L_kk @ L_panel.T = A[e:, s:e].T  (forward substitution)
            panel_t = solve_lower_unblocked(l_kk, a[e:, s:e].T)
            panel = panel_t.T                                   # (n-e, b)
            l_out = l_out.at[e:, s:e].set(panel)
            # Trailing SYRK update (the MXU-heavy part).
            a = a.at[e:, e:].add(-(panel @ panel.T))
    return l_out


@functools.partial(jax.jit, static_argnames=("block",))
def solve_lower_blocked(l, c, *, block: int = DEFAULT_BLOCK):
    """Blocked forward substitution: solve L @ Y = C, L (N,N) lower, C (N,D)."""
    n = l.shape[0]
    b = _pick_block(n, block)
    nb = n // b
    y = jnp.zeros_like(c)
    for k in range(nb):
        s = k * b
        e = s + b
        rhs = c[s:e, :] - l[s:e, :s] @ y[:s, :] if s > 0 else c[s:e, :]
        y = y.at[s:e, :].set(solve_lower_unblocked(l[s:e, s:e], rhs))
    return y


@functools.partial(jax.jit, static_argnames=("block",))
def solve_upper_blocked(u, c, *, block: int = DEFAULT_BLOCK):
    """Blocked backward substitution: solve U @ Y = C, U (N,N) upper, C (N,D)."""
    n = u.shape[0]
    b = _pick_block(n, block)
    nb = n // b
    y = jnp.zeros_like(c)
    for k in reversed(range(nb)):
        s = k * b
        e = s + b
        rhs = c[s:e, :] - u[s:e, e:] @ y[e:, :] if e < n else c[s:e, :]
        y = y.at[s:e, :].set(solve_upper_unblocked(u[s:e, s:e], rhs))
    return y


def spd_solve(k_mat, rhs, *, block: int = DEFAULT_BLOCK, eps: float = 0.0):
    """Solve K @ X = RHS for SPD K via blocked Cholesky + two solves."""
    l = chol_blocked(k_mat, block=block, eps=eps)
    y = solve_lower_blocked(l, rhs, block=block)
    return solve_upper_blocked(l.T, y, block=block)
