# L1 correctness: Pallas gram / cross kernels vs the pure-numpy oracle,
# swept over shapes, dtilings and bandwidths with hypothesis.
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gram, ref


def _mk(n, l, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((n, l)) * scale).astype(np.float32)


def run_gram(x, mask, rho, rbf, tile=gram.DEFAULT_TILE):
    return np.asarray(gram.gram_matrix(
        jnp.asarray(x), jnp.asarray(mask.reshape(-1, 1).astype(np.float32)),
        jnp.asarray(np.array([[rho]], np.float32)), rbf=rbf, tile=tile))


@pytest.mark.parametrize("rbf", [False, True])
@pytest.mark.parametrize("n,l", [(32, 8), (128, 64), (256, 64), (256, 256)])
def test_gram_matches_ref_unmasked(n, l, rbf):
    x = _mk(n, l, seed=n + l)
    mask = np.ones(n, np.float32)
    got = run_gram(x, mask, 0.03, rbf)
    want = ref.ref_masked_gram(x, mask, 0.03, rbf)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("rbf", [False, True])
def test_gram_padding_is_identity_block(rbf):
    n, l, n_real = 256, 32, 177
    x = _mk(n, l, seed=7)
    x[n_real:] = 0.0
    mask = np.zeros(n, np.float32)
    mask[:n_real] = 1.0
    got = run_gram(x, mask, 0.1, rbf)
    # padded block is exactly the identity
    np.testing.assert_array_equal(got[n_real:, n_real:], np.eye(n - n_real))
    np.testing.assert_array_equal(got[:n_real, n_real:], 0.0)
    np.testing.assert_array_equal(got[n_real:, :n_real], 0.0)
    want = ref.ref_masked_gram(x[:n_real], mask[:n_real], 0.1, rbf)
    np.testing.assert_allclose(got[:n_real, :n_real], want, rtol=1e-5, atol=1e-5)


def test_gram_rbf_unit_diagonal_and_symmetry():
    x = _mk(128, 16, seed=3, scale=2.0)
    got = run_gram(x, np.ones(128, np.float32), 0.7, rbf=True)
    # f32 cancellation in ||xi||^2+||xj||^2-2xi.xj bounds diagonal accuracy
    np.testing.assert_allclose(np.diag(got), 1.0, atol=5e-5)
    np.testing.assert_allclose(got, got.T, atol=1e-6)
    assert got.min() >= 0.0 and got.max() <= 1.0 + 5e-5


@settings(deadline=None, max_examples=25)
@given(
    n=st.sampled_from([16, 64, 96, 128]),
    l=st.sampled_from([4, 16, 33, 64]),
    rho=st.floats(1e-3, 5.0),
    rbf=st.booleans(),
    frac=st.floats(0.3, 1.0),
    seed=st.integers(0, 2**16),
)
def test_gram_hypothesis_sweep(n, l, rho, rbf, frac, seed):
    x = _mk(n, l, seed=seed)
    n_real = max(2, int(n * frac))
    x[n_real:] = 0.0
    mask = np.zeros(n, np.float32)
    mask[:n_real] = 1.0
    got = run_gram(x, mask, rho, rbf, tile=64)
    want = ref.ref_masked_gram(x, mask, rho, rbf)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("rbf", [False, True])
@pytest.mark.parametrize("ne,nt,l", [(64, 128, 16), (128, 256, 64), (96, 64, 32)])
def test_cross_kernel_matches_ref(ne, nt, l, rbf):
    xe = _mk(ne, l, seed=ne)
    xt = _mk(nt, l, seed=nt + 1)
    mask = np.ones((nt, 1), np.float32)
    got = np.asarray(gram.cross_kernel(
        jnp.asarray(xe), jnp.asarray(xt), jnp.asarray(mask),
        jnp.asarray(np.array([[0.2]], np.float32)), rbf=rbf, tile=64))
    want = (ref.ref_cross_rbf(xe, xt, 0.2) if rbf
            else ref.ref_cross_linear(xe, xt))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_cross_kernel_train_mask_zeroes_columns():
    xe = _mk(32, 8, seed=11)
    xt = _mk(64, 8, seed=12)
    mask = np.ones((64, 1), np.float32)
    mask[40:] = 0.0
    got = np.asarray(gram.cross_kernel(
        jnp.asarray(xe), jnp.asarray(xt), jnp.asarray(mask),
        jnp.asarray(np.array([[0.2]], np.float32)), rbf=True, tile=32))
    np.testing.assert_array_equal(got[:, 40:], 0.0)


@pytest.mark.parametrize("tile", [16, 32, 128])
def test_gram_tile_size_invariance(tile):
    x = _mk(128, 32, seed=5)
    mask = np.ones(128, np.float32)
    base = run_gram(x, mask, 0.4, True, tile=gram.DEFAULT_TILE)
    got = run_gram(x, mask, 0.4, True, tile=tile)
    # tile shape changes the f32 dot accumulation order; bitwise equality
    # is not expected, only tight agreement
    np.testing.assert_allclose(got, base, rtol=2e-5, atol=2e-5)
