# L2 correctness: the fit / project graphs vs the literal AKDA/AKSDA
# oracles, the padding-exactness contract, and the paper's simultaneous-
# reduction identities (Eqs. 45-47, 71-73) evaluated on the graph outputs.
import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


def _problem(n_real, l, c, seed, scale=0.6):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((n_real, l)) * scale).astype(np.float32)
    # shift class means so classes are distinguishable
    labels = np.sort(rng.integers(0, c, n_real))
    # ensure every class occupied
    labels[:c] = np.arange(c)
    labels = np.sort(labels)
    for i in range(c):
        x[labels == i] += rng.standard_normal(l).astype(np.float32) * 0.8
    return x, labels


def _pad(x, theta, n_pad, d_max=32):
    n, l = x.shape
    xp = np.zeros((n_pad, l), np.float32)
    xp[:n] = x
    th = np.zeros((n_pad, d_max), np.float32)
    th[:n, :theta.shape[1]] = theta
    mask = np.zeros((n_pad, 1), np.float32)
    mask[:n] = 1.0
    return xp, th, mask


def run_fit(xp, th, rho, mask, rbf=True, eps=1e-3):
    return np.asarray(model.akda_fit(
        jnp.asarray(xp), jnp.asarray(th),
        jnp.asarray(np.array([[rho]], np.float32)), jnp.asarray(mask),
        rbf=rbf, eps=eps))


# With the linear kernel K = X X^T is rank <= L, so a tiny ridge makes the
# solve ill-conditioned and f32-vs-f64 comparison meaningless; use a ridge
# large enough that kappa(K + eps I) is moderate.
def _eps_for(rbf):
    return 1e-3 if rbf else 1e-1


@pytest.mark.parametrize("rbf", [True, False])
@pytest.mark.parametrize("n_real,c", [(100, 2), (150, 3), (200, 5)])
def test_fit_matches_oracle(n_real, c, rbf):
    x, labels = _problem(n_real, 32, c, seed=n_real + c)
    rho, eps = 0.05, _eps_for(rbf)
    psi_ref, theta, _ = ref.ref_akda_fit(x, labels, c, rho, rbf=rbf, eps=eps)
    xp, th, mask = _pad(x, theta, 256)
    psi = run_fit(xp, th, rho, mask, rbf=rbf, eps=eps)
    np.testing.assert_allclose(psi[:n_real, :c - 1], psi_ref,
                               rtol=2e-3, atol=2e-4)


def test_fit_padding_exactly_zero():
    x, labels = _problem(180, 16, 3, seed=0)
    _, theta, _ = ref.ref_akda_fit(x, labels, 3, 0.1)
    xp, th, mask = _pad(x, theta, 256)
    psi = run_fit(xp, th, 0.1, mask)
    assert np.abs(psi[180:]).max() == 0.0       # padded rows exactly zero
    assert np.abs(psi[:, 2:]).max() == 0.0      # unused columns exactly zero


def test_fit_bucket_invariance():
    """Same problem through two different buckets gives the same psi."""
    x, labels = _problem(120, 16, 3, seed=2)
    _, theta, _ = ref.ref_akda_fit(x, labels, 3, 0.2)
    xp1, th1, m1 = _pad(x, theta, 128)
    xp2, th2, m2 = _pad(x, theta, 256)
    p1 = run_fit(xp1, th1, 0.2, m1)
    p2 = run_fit(xp2, th2, 0.2, m2)
    np.testing.assert_allclose(p1[:120, :2], p2[:120, :2], rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("rbf", [True, False])
def test_project_matches_oracle(rbf):
    x, labels = _problem(96, 16, 3, seed=5)
    rng = np.random.default_rng(6)
    xte = rng.standard_normal((64, 16)).astype(np.float32)
    eps = _eps_for(rbf)
    psi_ref, theta, _ = ref.ref_akda_fit(x, labels, 3, 0.1, rbf=rbf, eps=eps)
    xp, th, mask = _pad(x, theta, 128)
    psi = run_fit(xp, th, 0.1, mask, rbf=rbf, eps=eps)
    z = np.asarray(model.akda_project(
        jnp.asarray(xp), jnp.asarray(xte), jnp.asarray(psi),
        jnp.asarray(np.array([[0.1]], np.float32)),
        jnp.asarray(mask), rbf=rbf))
    z_ref = ref.ref_akda_project(x, xte, psi_ref, 0.1, rbf=rbf)
    np.testing.assert_allclose(z[:, :2], z_ref, rtol=2e-3, atol=2e-4)


def test_simultaneous_reduction_identities():
    """Gamma^T Sigma_b Gamma = I, Gamma^T Sigma_w Gamma = 0,
    Gamma^T Sigma_t Gamma = I  (Eqs. 45-47) — evaluated through the kernel
    matrices: Psi^T S_b Psi etc., with S_* from the literal definitions."""
    x, labels = _problem(90, 8, 3, seed=8)
    rho, c = 0.3, 3
    psi, theta, _ = ref.ref_akda_fit(x, labels, c, rho, eps=0.0)
    sb, sw, st = ref.ref_scatter_kernel_matrices(x, labels, c, rho)
    d = c - 1
    np.testing.assert_allclose(psi.T @ sb @ psi, np.eye(d), atol=5e-3)
    np.testing.assert_allclose(psi.T @ sw @ psi, np.zeros((d, d)), atol=5e-3)
    np.testing.assert_allclose(psi.T @ st @ psi, np.eye(d), atol=5e-3)


def test_central_factor_identities():
    """S_b = K C_b K, S_w = K C_w K, S_t = K C_t K (Sec. 4.1), plus
    C_t = C_b + C_w, C_b C_w = 0, idempotency and ranks (Sec. 4.2)."""
    x, labels = _problem(60, 8, 4, seed=9)
    k = ref.ref_gram_rbf(x, 0.2)
    cb, cw, ct = ref.ref_central_factors(labels, 4)
    sb, sw, st = ref.ref_scatter_kernel_matrices(x, labels, 4, 0.2)
    np.testing.assert_allclose(k @ cb @ k, sb, atol=1e-6 * np.abs(sb).max())
    np.testing.assert_allclose(k @ cw @ k, sw, atol=1e-6 * np.abs(sw).max())
    np.testing.assert_allclose(k @ ct @ k, st, atol=1e-6 * np.abs(st).max())
    np.testing.assert_allclose(cb + cw, ct, atol=1e-12)
    np.testing.assert_allclose(cb @ cw, 0.0, atol=1e-12)
    for m in (cb, cw, ct):
        np.testing.assert_allclose(m @ m, m, atol=1e-10)   # idempotent
    assert np.linalg.matrix_rank(cb) == 3                  # C-1
    assert np.linalg.matrix_rank(cw) == 60 - 4             # N-C
    assert np.linalg.matrix_rank(ct) == 60 - 1             # N-1


def test_binary_theta_analytic_matches_evd():
    """Eq. 50 equals the EVD route (up to sign)."""
    labels = np.array([0] * 30 + [1] * 70)
    t_evd = ref.ref_theta(labels, 2)[:, 0]
    t_ana = ref.ref_theta_binary(30, 70)[:, 0]
    s = np.sign(t_evd[0] * t_ana[0])
    np.testing.assert_allclose(t_evd, s * t_ana, atol=1e-12)
    assert abs(np.linalg.norm(t_ana) - 1.0) < 1e-12


def test_theta_columns_orthonormal():
    labels = np.sort(np.random.default_rng(3).integers(0, 5, 200))
    labels[:5] = np.arange(5)
    theta = ref.ref_theta(np.sort(labels), 5)
    np.testing.assert_allclose(theta.T @ theta, np.eye(4), atol=1e-12)


def test_aksda_core_matrix_properties():
    """O_bs is SPSD with rank H-1 and null vector n-dot (Sec. 5.2)."""
    class_of = np.array([0, 0, 1, 1, 2])       # 3 classes, 5 subclasses
    counts = np.array([10, 12, 20, 8, 15])
    obs = ref.ref_core_matrix_subclass(class_of, counts)
    w = np.linalg.eigvalsh(obs)
    assert w.min() > -1e-10
    assert (w > 1e-10).sum() == 4              # H - 1
    ndot = np.sqrt(counts)
    np.testing.assert_allclose(obs @ ndot, 0.0, atol=1e-10)


def test_aksda_reduction_identities():
    """V^T C_bs V = Omega, V^T C_ws V = 0, V^T C_t V = I (Eqs. 67-69)."""
    rng = np.random.default_rng(11)
    sub_labels = np.sort(rng.integers(0, 5, 120))
    sub_labels[:5] = np.arange(5)
    sub_labels = np.sort(sub_labels)
    class_of = np.array([0, 0, 1, 1, 2])
    n = sub_labels.size
    counts = np.array([(sub_labels == j).sum() for j in range(5)])
    v, w = ref.ref_v_matrix(sub_labels, class_of, 5)
    r = np.zeros((n, 5))
    r[np.arange(n), sub_labels] = 1.0
    obs = ref.ref_core_matrix_subclass(class_of, counts)
    nh = np.diag(1.0 / np.sqrt(counts))
    cbs = r @ nh @ obs @ nh @ r.T
    cws = np.eye(n) - r @ np.diag(1.0 / counts) @ r.T
    ct = np.eye(n) - np.ones((n, n)) / n
    np.testing.assert_allclose(v.T @ cbs @ v, np.diag(w), atol=1e-10)
    np.testing.assert_allclose(v.T @ cws @ v, 0.0, atol=1e-10)
    np.testing.assert_allclose(v.T @ ct @ v, np.eye(4), atol=1e-10)


def test_aksda_fit_through_graph():
    """AKSDA uses the same fit graph with theta := V."""
    rng = np.random.default_rng(13)
    n_real, l = 120, 16
    x = rng.standard_normal((n_real, l)).astype(np.float32)
    sub_labels = np.sort(rng.integers(0, 4, n_real))
    sub_labels[:4] = np.arange(4)
    sub_labels = np.sort(sub_labels)
    class_of = np.array([0, 0, 1, 1])
    psi_ref, v, _ = ref.ref_aksda_fit(x, sub_labels, class_of, 4, 0.15)
    xp, th, mask = _pad(x, v, 128)
    psi = run_fit(xp, th, 0.15, mask)
    np.testing.assert_allclose(psi[:n_real, :3], psi_ref, rtol=2e-3, atol=2e-4)
