# L1 correctness: blocked Cholesky + triangular solves vs numpy oracles.
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import chol, ref


def _spd(n, seed, cond=None):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    m = a @ a.T / n + np.eye(n, dtype=np.float32)
    if cond is not None:
        # stretch the spectrum to a target condition number
        w, v = np.linalg.eigh(m.astype(np.float64))
        w = np.geomspace(1.0 / cond, 1.0, n)
        m = (v * w) @ v.T
    return m.astype(np.float32)


@pytest.mark.parametrize("n", [4, 16, 64, 128])
def test_chol_unblocked_matches_numpy(n):
    a = _spd(n, seed=n)
    l = np.asarray(chol.chol_unblocked(jnp.asarray(a)))
    np.testing.assert_allclose(l, ref.ref_chol(a.astype(np.float64)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,block", [(64, 16), (128, 32), (256, 64), (256, 128),
                                     (192, 64), (256, 256)])
def test_chol_blocked_reconstructs(n, block):
    a = _spd(n, seed=n + block)
    l = np.asarray(chol.chol_blocked(jnp.asarray(a), block=block))
    np.testing.assert_allclose(l @ l.T, a, rtol=1e-4, atol=1e-4)
    assert np.abs(np.triu(l, 1)).max() == 0.0


def test_chol_blocked_equals_unblocked():
    a = _spd(128, seed=9)
    lb = np.asarray(chol.chol_blocked(jnp.asarray(a), block=32))
    lu = np.asarray(chol.chol_unblocked(jnp.asarray(a)))
    np.testing.assert_allclose(lb, lu, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,d,block", [(64, 4, 16), (128, 32, 32), (96, 8, 32)])
def test_triangular_solves(n, d, block):
    rng = np.random.default_rng(n + d)
    a = _spd(n, seed=2 * n)
    l = np.linalg.cholesky(a.astype(np.float64)).astype(np.float32)
    c = rng.standard_normal((n, d)).astype(np.float32)
    y = np.asarray(chol.solve_lower_blocked(jnp.asarray(l), jnp.asarray(c),
                                            block=block))
    np.testing.assert_allclose(l @ y, c, rtol=1e-3, atol=1e-3)
    z = np.asarray(chol.solve_upper_blocked(jnp.asarray(l.T), jnp.asarray(c),
                                            block=block))
    np.testing.assert_allclose(l.T @ z, c, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n,d", [(64, 3), (256, 31)])
def test_spd_solve_matches_numpy(n, d):
    rng = np.random.default_rng(n)
    a = _spd(n, seed=n + 5)
    b = rng.standard_normal((n, d)).astype(np.float32)
    x = np.asarray(chol.spd_solve(jnp.asarray(a), jnp.asarray(b)))
    want = ref.ref_spd_solve(a.astype(np.float64), b.astype(np.float64))
    np.testing.assert_allclose(x, want, rtol=2e-3, atol=2e-3)


def test_spd_solve_ill_conditioned_stays_finite():
    a = _spd(128, seed=1, cond=1e6)
    b = np.ones((128, 2), np.float32)
    x = np.asarray(chol.spd_solve(jnp.asarray(a), jnp.asarray(b), eps=1e-3))
    assert np.isfinite(x).all()


def test_chol_blockdiag_identity_pad():
    """The padding contract: chol(blockdiag(A, I)) = blockdiag(chol(A), I)."""
    a = _spd(96, seed=4)
    n = 128
    ap = np.eye(n, dtype=np.float32)
    ap[:96, :96] = a
    l = np.asarray(chol.chol_blocked(jnp.asarray(ap), block=32))
    la = np.asarray(chol.chol_blocked(jnp.asarray(a), block=32))
    np.testing.assert_allclose(l[:96, :96], la, atol=1e-6)
    np.testing.assert_array_equal(l[96:, 96:], np.eye(32))
    np.testing.assert_array_equal(l[96:, :96], 0.0)


@settings(deadline=None, max_examples=20)
@given(
    n=st.sampled_from([8, 32, 48, 64]),
    d=st.integers(1, 8),
    block=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**16),
)
def test_spd_solve_hypothesis(n, d, block, seed):
    rng = np.random.default_rng(seed)
    a = _spd(n, seed=seed)
    b = rng.standard_normal((n, d)).astype(np.float32)
    x = np.asarray(chol.spd_solve(jnp.asarray(a), jnp.asarray(b), block=block))
    np.testing.assert_allclose(a @ x, b, rtol=5e-3, atol=5e-3)
