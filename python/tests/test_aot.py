# AOT emitter sanity: lowered HLO text parses, manifest is consistent, and
# the quick bucket round-trips through jax's own HLO-text path.
import json
import pathlib
import subprocess
import sys

import pytest

from compile import aot


def test_to_hlo_text_contains_entry():
    low = aot.lower_fit(256, 64, "rbf")
    text = aot.to_hlo_text(low)
    assert "ENTRY" in text and "f32[256,64]" in text
    assert "f32[256,32]" in text            # theta / psi
    assert "custom-call" not in text.lower(), \
        "artifact must not contain LAPACK custom-calls (unrunnable on PJRT)"


def test_project_hlo_shapes():
    low = aot.lower_project(256, 1024, 64, "linear")
    text = aot.to_hlo_text(low)
    assert "f32[1024,64]" in text and "f32[256,64]" in text
    assert "custom-call" not in text.lower()


def test_gram_hlo_no_custom_calls():
    low = aot.lower_gram(256, 64, "rbf")
    assert "custom-call" not in aot.to_hlo_text(low).lower()


@pytest.mark.slow
def test_quick_emit(tmp_path):
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--quick"],
        check=True, cwd=pathlib.Path(__file__).resolve().parents[1])
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["d_max"] == aot.D_MAX
    names = {e["name"] for e in manifest["entries"]}
    assert "fit_rbf_n256_l64" in names
    assert "project_linear_ntr256_nte256_l64" in names
    for e in manifest["entries"]:
        assert (tmp_path / e["file"]).exists()
        assert all("shape" in i for i in e["inputs"])
