//! Sec. 6.2 toy example (Figs. 2–3): binary AKDA on an rgbd-like
//! target-vs-rest problem; dumps scatter + projection CSVs and prints the
//! timing decomposition.
//!
//! Run: cargo run --release --example toy_example [out_dir]

mod toy_impl;

fn main() -> anyhow::Result<()> {
    let out = std::env::args().nth(1).unwrap_or_else(|| "toy_output".into());
    let artifacts = std::env::var("AKDA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    toy_impl::run(std::path::Path::new(&out), std::path::Path::new(&artifacts))
}
