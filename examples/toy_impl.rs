// Shared implementation of the Sec. 6.2 toy example — included by both
// `examples/toy_example.rs` and the `akda toy` subcommand.
//
// Reproduces the paper's walk-through: a binary problem shaped like the
// rgbd "apple vs rest-of-world" task (N1 ≪ N2), the analytic core-matrix
// eigenvector ξ (Eq. 49) and target θ (Eq. 50), the AKDA fit with the
// linear kernel, timing decomposition (K vs solve), and the CSV dumps
// behind Fig. 2 (input-space scatter) and Fig. 3 (1-D AKDA projection).

use std::path::Path;

use akda::da::core;
use akda::data::csv::save_matrix;
use akda::data::synthetic::{gaussian_classes, GaussianSpec};
use akda::kernels::{gram, Kernel};
use akda::linalg::{chol, Mat};
use akda::util::timer::timed;

pub fn run(out_dir: &Path, artifacts_dir: &Path) -> anyhow::Result<()> {
    std::fs::create_dir_all(out_dir)?;
    // rgbd-100Ex-shaped problem, scaled into the 2048 bucket:
    // N1 = 40 target observations, N2 = 2000 rest-of-world.
    let (n1, n2, dim) = (40usize, 2000usize, 64usize);
    let (x, labels) = gaussian_classes(&GaussianSpec {
        n_classes: 2,
        n_per_class: vec![n1, n2],
        dim,
        class_sep: 2.2,
        noise: 1.0,
        // rest-of-world is everything else → strongly multimodal
        modes_per_class: 6,
        seed: 42,
    });
    let n = n1 + n2;
    println!("toy problem: N1={n1} target, N2={n2} rest-of-world, L={dim}");

    // Step 1-2: analytic binary eigenvectors (Eqs. 49-50)
    let xi = [
        (n2 as f64 / n as f64).sqrt(),
        -(n1 as f64 / n as f64).sqrt(),
    ];
    println!("xi    = [{:.4}, {:.4}]  (Eq. 49)", xi[0], xi[1]);
    let theta = core::theta_binary(&labels);
    println!(
        "theta = [{:.5} x{n1}, {:.5} x{n2}]  (Eq. 50), ||theta|| = {:.6}",
        theta[(0, 0)],
        theta[(n - 1, 0)],
        theta.data().iter().map(|v| v * v).sum::<f64>().sqrt()
    );

    // Steps 3-4 with the linear kernel (as in the paper's toy), timed.
    let kernel = Kernel::Linear;
    let (mut k, t_gram) = timed(|| gram(&x, kernel));
    // same absolute ridge the AOT artifact bakes (Sec. 4.3 regularization),
    // so the native and PJRT paths solve the identical system
    k.add_ridge(1e-3);
    let (psi, t_solve) = timed(|| chol::spd_solve(&k, &theta, 64).expect("SPD"));
    println!(
        "AKDA learn time: {:.2}s total  (K: {:.2}s, solve: {:.2}s)",
        t_gram + t_solve,
        t_gram,
        t_solve
    );

    // Optional: same fit through the PJRT artifacts for comparison.
    if artifacts_dir.join("manifest.json").exists() {
        if let Ok(engine) = akda::runtime::PjrtEngine::from_dir(artifacts_dir) {
            // warm the executable cache, then time
            let _ = engine.fit(&x, &theta, kernel);
            let (psi_pjrt, t_pjrt) = timed(|| engine.fit(&x, &theta, kernel).expect("fit"));
            let z_n = k.matmul(&psi);
            let z_p = k.matmul(&psi_pjrt);
            let rel = z_n.sub(&z_p).max_abs() / z_n.max_abs().max(1e-12);
            println!("AKDA-PJRT learn time: {t_pjrt:.2}s (warm), vs native rel diff {rel:.2e}");
        }
    }

    // Fig. 2 data: first two input dimensions + label
    let fig2 = Mat::from_fn(n, 3, |i, j| match j {
        0 => x[(i, 0)],
        1 => x[(i, 1)],
        _ => labels[i] as f64,
    });
    save_matrix(&out_dir.join("fig2_scatter.csv"), &fig2)?;

    // Fig. 3 data: 1-D AKDA projection z_n = (K psi)_n + label
    let z = k.matmul(&psi);
    let fig3 = Mat::from_fn(n, 2, |i, j| if j == 0 { z[(i, 0)] } else { labels[i] as f64 });
    save_matrix(&out_dir.join("fig3_projection.csv"), &fig3)?;

    // headline check from the paper: classes separate in 1-D
    let m0 = (0..n1).map(|i| z[(i, 0)]).sum::<f64>() / n1 as f64;
    let m1 = (n1..n).map(|i| z[(i, 0)]).sum::<f64>() / n2 as f64;
    let s0 = ((0..n1).map(|i| (z[(i, 0)] - m0).powi(2)).sum::<f64>() / n1 as f64).sqrt();
    let s1 = ((n1..n).map(|i| (z[(i, 0)] - m1).powi(2)).sum::<f64>() / n2 as f64).sqrt();
    let gap = (m0 - m1).abs() / (s0 + s1).max(1e-12);
    println!("1-D separation: |mu0-mu1|/(s0+s1) = {gap:.2} (classes well separated: {})",
             gap > 1.0);
    println!("wrote {:?} and {:?}", out_dir.join("fig2_scatter.csv"),
             out_dir.join("fig3_projection.csv"));
    Ok(())
}
