//! Shard-and-serve, end to end: train one model as K stride shards, merge
//! the shard accumulators into a published model (bit-identical no matter
//! the merge order), then front it with a FLEET OF FLEETS — two
//! independent `FleetService` processes watching the same registry, with
//! a round-robin router fanning scoring requests across them. Publishing
//! the merged model hot-swaps BOTH fleets mid-traffic.
//!
//! This is the in-process mirror of the operational story: `akda train
//! --shard i/k` on K machines, `akda merge --publish` on one, N × `akda
//! serve --fleet --watch` behind a load balancer.
//!
//! Run: cargo run --release --example shard_router

use std::sync::Arc;
use std::time::Duration;

use akda::coordinator::fleet::{FleetOptions, FleetService};
use akda::coordinator::protocol::approx_config;
use akda::coordinator::{DetectorBank, Hyper, MethodId};
use akda::da::akda_stream::{BlockedProjection, PreparedStream, TiledAccumulator};
use akda::da::Projection;
use akda::data::stream::{
    reservoir_sample_labeled, BlockSource, MemBlockSource, StridedBlockSource,
};
use akda::data::{by_name, Condition};
use akda::model::codec::ApproxResume;
use akda::model::shard::basis_fingerprint;
use akda::model::update::{train_svm_bank, DEFAULT_RESERVOIR_CAP, DEFAULT_UPDATE_SEED};
use akda::model::{
    encode_shard, ModelManifest, ModelRegistry, ShardPiece, ShardSet,
};
use akda::util::rng::shard_seed;

const SHARDS: usize = 3;
const BLOCK_ROWS: usize = 256;

fn main() -> anyhow::Result<()> {
    let spec = by_name("eth80").expect("dataset in registry");
    let split = spec.split(Condition::Ex100);
    let hp = Hyper { rho: 0.05, c: 1.0, h: 2, ..Default::default() };
    let ap = approx_config(MethodId::AkdaNystrom, hp, 1e-3);

    // ---- map side: K shard trains, each over its own stride of the
    // stream (here in one process; operationally one per machine) -------
    let mut full = MemBlockSource::new(&split.x_train, &split.y_train, BLOCK_ROWS);
    let map: Arc<dyn akda::approx::FeatureMap> = Arc::from(ap.build_map_stream(&mut full)?);
    let basis = basis_fingerprint(map.as_ref())?;
    let mut set = ShardSet::new();
    for index in 0..SHARDS {
        let mut src = StridedBlockSource::new(
            MemBlockSource::new(&split.x_train, &split.y_train, BLOCK_ROWS),
            index,
            SHARDS,
        )?;
        let mut acc = TiledAccumulator::new(map.dim());
        src.reset()?;
        while let Some(block) = src.next_block()? {
            let phi = map.transform(&block.x);
            acc.absorb(&phi, &block.labels)?;
        }
        let agg = acc.into_aggregates(split.n_classes)?;
        let (reservoir, reservoir_labels, seen) = reservoir_sample_labeled(
            &mut src,
            DEFAULT_RESERVOIR_CAP,
            shard_seed(DEFAULT_UPDATE_SEED, index, SHARDS),
        )?;
        let piece = ShardPiece {
            index,
            count: SHARDS,
            basis,
            block_rows: BLOCK_ROWS,
            map: Arc::clone(&map),
            resume: ApproxResume {
                gram: agg.gram,
                class_sums: agg.class_sums,
                counts: agg.counts,
                reservoir,
                reservoir_labels,
                seen,
                eps: ap.eps,
            },
            meta: Default::default(),
        };
        // round-trip through the artifact codec, as the CLI would
        let art = encode_shard(&piece)?;
        set.insert(akda::model::decode_shard(&art)?)?;
        println!("shard {index}/{SHARDS} accumulated");
    }

    // ---- reduce side: merge, factorize once, publish ------------------
    let merged = set.finalize(DEFAULT_RESERVOIR_CAP)?;
    let prep = PreparedStream::from_aggregates(
        Arc::clone(&merged.map),
        merged.aggregates,
        merged.eps,
        akda::linalg::chol::DEFAULT_BLOCK,
    )?;
    let w = prep.solve_w_multiclass()?;
    let proj = BlockedProjection { map: Arc::clone(&prep.map), w, block_rows: BLOCK_ROWS };
    let z = proj.project(&split.x_train);
    let svms = train_svm_bank(&z, &split.y_train, split.n_classes);
    let bank = Arc::new(DetectorBank { projection: Box::new(proj), svms });

    let dir = std::env::temp_dir().join(format!("akda-shard-router-{}", std::process::id()));
    let registry = ModelRegistry::open(&dir);
    let artifact = akda::model::encode_bank(&bank, "akda-nystrom")?;
    let manifest = ModelManifest {
        method: "akda-nystrom".into(),
        dataset: "eth80".into(),
        n_classes: split.n_classes,
        input_dim: split.x_train.cols(),
        ..Default::default()
    };
    let entry = registry.publish("eth80", &artifact, &manifest)?;
    println!("published {} from {SHARDS} merged shards", entry.spec());

    // ---- fleet of fleets: two serving processes, one registry ---------
    let opts = || FleetOptions { watch: Some(Duration::from_millis(50)), ..Default::default() };
    let fleet_a = FleetService::start(&ModelRegistry::open(&dir), opts())?;
    let fleet_b = FleetService::start(&ModelRegistry::open(&dir), opts())?;
    let clients = [fleet_a.client(), fleet_b.client()];

    // round-robin router: request i → fleet i mod 2
    let mut correct = 0usize;
    for i in 0..split.x_test.rows() {
        let scores = clients[i % clients.len()]
            .score("eth80", split.x_test.row(i).to_vec())
            .map_err(|e| anyhow::anyhow!("route {i}: {e}"))?;
        let pred = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).expect("finite score"))
            .map(|(c, _)| c)
            .unwrap_or(0);
        if pred == split.y_test[i] {
            correct += 1;
        }
    }
    println!(
        "routed {} requests across {} fleets: accuracy {:.2}% (A served {}, B served {})",
        split.x_test.rows(),
        clients.len(),
        100.0 * correct as f64 / split.x_test.rows() as f64,
        fleet_a.stats().requests,
        fleet_b.stats().requests,
    );

    // republish (a new version) and watch both fleets hot-swap it
    let v2 = registry.publish("eth80", &artifact, &manifest)?;
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let a = fleet_a.served_versions();
        let b = fleet_b.served_versions();
        let caught_up = |v: &[(String, u32)]| v.iter().any(|(_, ver)| *ver == v2.version);
        if caught_up(&a) && caught_up(&b) {
            println!("both fleets hot-swapped to v{} without restart", v2.version);
            break;
        }
        anyhow::ensure!(std::time::Instant::now() < deadline, "fleets never swapped");
        std::thread::sleep(Duration::from_millis(20));
    }
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
