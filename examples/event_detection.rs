//! End-to-end driver: the full video-event-detection pipeline of Sec. 6 on
//! the (scaled) med10 dataset — every layer composes here:
//!
//!   L1/L2 AOT artifacts (Pallas gram + blocked Cholesky, `make artifacts`)
//!     → L3 PJRT engine (bucketed, padded, cached executables)
//!     → coordinator protocol (per-event one-vs-rest jobs on the work pool,
//!       3-fold CV over the paper's hyper-parameter grid)
//!     → LSVM detectors → MAP + training-time speedup over KDA.
//!
//! This regenerates the paper's headline claim (accelerated training at
//! equal-or-better MAP) on a real workload; results land in
//! EXPERIMENTS.md. Run: cargo run --release --example event_detection

use std::sync::Arc;

use akda::coordinator::{evaluate_ovr, select_hyper, EvalConfig, Hyper, MethodId, WorkPool};
use akda::data::{by_name, Condition};
use akda::eval::tables::{map_table, speedup_table, DatasetRow};
use akda::runtime::PjrtEngine;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("AKDA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let engine = Arc::new(PjrtEngine::from_dir(std::path::Path::new(&artifacts))?);

    let spec = by_name("med10").expect("registry");
    let cond = Condition::Ex100;
    let split = spec.split(cond);
    println!(
        "med10 [{}]: {} events, {} train / {} test observations, L={}",
        cond.name(),
        split.n_classes,
        split.y_train.len(),
        split.y_test.len(),
        split.x_train.cols()
    );

    let cfg = EvalConfig {
        rho_grid: vec![0.01, 0.05, 0.1],
        c_grid: vec![1.0, 10.0],
        h_grid: vec![2, 3],
        ..Default::default()
    };
    let pool = WorkPool::new(cfg.workers);

    // the headline comparison: conventional KDA/KSDA vs accelerated
    // AKDA/AKSDA (native + PJRT hot path) + the fast prior art SRKDA
    let methods = [
        MethodId::Kda,
        MethodId::Srkda,
        MethodId::Akda,
        MethodId::AkdaPjrt,
        MethodId::Ksda,
        MethodId::Aksda,
        MethodId::AksdaPjrt,
    ];

    let mut results = Vec::new();
    for id in methods {
        let hp = select_hyper(&split, id, &cfg, Some(&engine))?;
        println!(
            "{}: CV picked rho={} c={} h={}",
            id.name(),
            hp.rho,
            hp.c,
            hp.h
        );
        let res = evaluate_ovr(&split, id, hp, cfg.eps, Some(&engine), Some(&pool))?;
        println!(
            "  MAP={:.2}%  train={:.2}s  test={:.2}s",
            100.0 * res.map,
            res.train_s,
            res.test_s
        );
        results.push(res);
    }

    let rows = vec![DatasetRow { dataset: "med10".into(), results }];
    println!("\n{}", map_table("med10 event detection — MAP", &rows));
    println!("{}", speedup_table("speedup over KDA (train/test)", &rows));

    // headline assertions (the *shape* of the paper's result):
    let get = |m: &str| rows[0].get(m).cloned().expect(m);
    let (kda, akda) = (get("kda"), get("akda"));
    let speedup = kda.train_s / akda.train_s;
    println!("AKDA training speedup over KDA: {speedup:.1}x");
    println!("AKDA MAP - KDA MAP: {:+.2}%", 100.0 * (akda.map - kda.map));
    assert!(speedup > 2.0, "AKDA must be much faster than KDA");
    assert!(akda.map >= kda.map - 0.05, "AKDA must not lose accuracy");
    println!("\nend-to-end pipeline OK (all three layers composed)");
    Ok(())
}
