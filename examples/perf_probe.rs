//! Perf probe: decomposes the native AKDA fit into gram / Cholesky /
//! solve wall-clock + GF/s — the measurement tool behind EXPERIMENTS.md
//! §Perf. Run: cargo run --release --example perf_probe
use std::time::Instant;
use akda::data::synthetic::{gaussian_classes, GaussianSpec};
use akda::kernels::{gram, Kernel};
use akda::linalg::{chol, Mat};
use akda::da::core;

fn t<T>(reps: usize, mut f: impl FnMut() -> T) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps { std::hint::black_box(f()); }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    let n = 1024;
    let (x, labels) = gaussian_classes(&GaussianSpec{n_classes:2, n_per_class:vec![n/4, n-n/4], dim:64, class_sep:2.0, noise:0.8, modes_per_class:2, seed:9});
    let theta = core::theta_binary(&labels);
    let tg = t(3, || gram(&x, Kernel::Rbf{rho:0.1}));
    let mut k = gram(&x, Kernel::Rbf{rho:0.1}); k.add_ridge(1e-3);
    let tc = t(3, || chol::cholesky(&k, 64).unwrap());
    let l = chol::cholesky(&k, 64).unwrap();
    let ts = t(3, || { let y = chol::solve_lower(&l, &theta); chol::solve_upper_from_lower(&l, &y) });
    println!("N={n}: gram={:.4}s chol={:.4}s solves={:.4}s total={:.4}s", tg, tc, ts, tg+tc+ts);
    println!("chol GF/s: {:.2}", (n as f64).powi(3)/3.0/tc/1e9);
    println!("gram GF/s: {:.2}", 2.0*(n as f64)*(n as f64)*64.0/tg/1e9);
}
