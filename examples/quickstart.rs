//! Quickstart: fit AKDA on a small multi-class problem, project, and train
//! per-class detectors — the 20-line tour of the public API.
//!
//! Run: cargo run --release --example quickstart

use akda::da::{akda::Akda, DrMethod};
use akda::data::synthetic::{gaussian_classes, GaussianSpec};
use akda::eval::average_precision;
use akda::kernels::Kernel;
use akda::svm::{LinearSvm, LinearSvmConfig};

fn main() -> anyhow::Result<()> {
    // 1. A 5-class problem, 40 observations per class, 16-D features.
    let (x, labels) = gaussian_classes(&GaussianSpec {
        n_classes: 5,
        n_per_class: vec![40; 5],
        dim: 16,
        class_sep: 2.0,
        noise: 0.7,
        modes_per_class: 1,
        seed: 7,
    });
    let (x_test, y_test) = gaussian_classes(&GaussianSpec {
        n_classes: 5,
        n_per_class: vec![60; 5],
        dim: 16,
        class_sep: 2.0,
        noise: 0.7,
        modes_per_class: 1,
        seed: 7, // same centers (same seed), fresh noise comes from order
    });

    // 2. Fit AKDA: one Cholesky solve, no N x N eigenproblem (Alg. 1).
    let akda = Akda::new(Kernel::Rbf { rho: 0.1 });
    let projection = akda.fit(&x, &labels, 5)?;
    println!("discriminant subspace dimension: {}", projection.dim()); // C-1 = 4

    // 3. Project train + test into the discriminant subspace.
    let z_train = projection.project(&x);
    let z_test = projection.project(&x_test);

    // 4. One linear SVM per class on the projected features (Sec. 6.3).
    let mut maps = Vec::new();
    for cls in 0..5 {
        let y: Vec<f64> = labels.iter().map(|&l| if l == cls { 1.0 } else { -1.0 }).collect();
        let svm = LinearSvm::train(&z_train, &y, LinearSvmConfig::default());
        let scores = svm.decision_batch(&z_test);
        let positive: Vec<bool> = y_test.iter().map(|&l| l == cls).collect();
        let ap = average_precision(&scores, &positive);
        println!("class {cls}: AP = {:.1}%", 100.0 * ap);
        maps.push(ap);
    }
    println!("MAP = {:.1}%", 100.0 * maps.iter().sum::<f64>() / 5.0);
    Ok(())
}
