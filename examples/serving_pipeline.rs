//! Serving pipeline: train a multi-class detector bank with the
//! PJRT-accelerated AKDA, then serve concurrent scoring requests through
//! the micro-batching scoring service — reporting latency percentiles and
//! throughput (the coordinator's request path, Python-free).
//!
//! Run: cargo run --release --example serving_pipeline [dataset]

use std::sync::Arc;
use std::time::{Duration, Instant};

use akda::coordinator::{DetectorBank, ScoringService};
use akda::da::DrMethod;
use akda::data::{by_name, Condition};
use akda::kernels::Kernel;
use akda::runtime::{AkdaPjrt, PjrtEngine};
use akda::svm::{LinearSvm, LinearSvmConfig};

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "mscorid".into());
    let artifacts = std::env::var("AKDA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let spec = by_name(&name).expect("dataset in registry");
    let split = spec.split(Condition::Ex100);
    println!(
        "{name}: C={} train={} test={}",
        split.n_classes,
        split.y_train.len(),
        split.y_test.len()
    );

    // train through the accelerated path
    let engine = Arc::new(PjrtEngine::from_dir(std::path::Path::new(&artifacts))?);
    let t0 = Instant::now();
    let projection = AkdaPjrt { kernel: Kernel::Rbf { rho: 0.05 }, engine }
        .fit(&split.x_train, &split.y_train, split.n_classes)?;
    let z = projection.project(&split.x_train);
    let svms = (0..split.n_classes)
        .map(|cls| {
            let y: Vec<f64> = split
                .y_train
                .iter()
                .map(|&l| if l == cls { 1.0 } else { -1.0 })
                .collect();
            (format!("class{cls}"), LinearSvm::train(&z, &y, LinearSvmConfig::default()))
        })
        .collect();
    println!("bank trained in {:.2}s (fit + project + {} LSVMs)",
             t0.elapsed().as_secs_f64(), split.n_classes);

    let bank = Arc::new(DetectorBank { projection, svms });
    let svc = ScoringService::start(
        bank,
        split.x_train.cols(),
        128,
        Duration::from_millis(4),
    );
    let client = svc.client();

    // fire the whole test set as concurrent requests; collect latencies
    let t0 = Instant::now();
    let mut latencies: Vec<f64> = Vec::with_capacity(split.x_test.rows());
    let mut correct = 0usize;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for i in 0..split.x_test.rows() {
            let client = client.clone();
            let row = split.x_test.row(i).to_vec();
            handles.push(s.spawn(move || {
                let r0 = Instant::now();
                let scores = client.score(row).unwrap();
                (r0.elapsed().as_secs_f64(), scores)
            }));
        }
        for (i, h) in handles.into_iter().enumerate() {
            let (lat, scores) = h.join().unwrap();
            latencies.push(lat);
            let pred = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(c, _)| c)
                .unwrap();
            if pred == split.y_test[i] {
                correct += 1;
            }
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| latencies[((latencies.len() - 1) as f64 * p) as usize] * 1e3;
    let stats = svc.stats();
    println!(
        "served {} requests in {:.2}s — {:.0} req/s, accuracy {:.1}%",
        latencies.len(),
        wall,
        latencies.len() as f64 / wall,
        100.0 * correct as f64 / latencies.len() as f64
    );
    println!(
        "latency p50={:.1}ms p90={:.1}ms p99={:.1}ms; {} batches, max batch {}",
        pct(0.50),
        pct(0.90),
        pct(0.99),
        stats.batches,
        stats.max_batch
    );
    Ok(())
}
